//! # FedSVD — Practical Lossless Federated SVD over Billion-Scale Data
//!
//! Reproduction of Chai et al., KDD 2022 (DOI 10.1145/3534678.3539402) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the federated coordinator: trusted authority
//!   (TA), computation service provider (CSP) and user roles, removable
//!   orthogonal masking, secure aggregation, network simulation, disk
//!   offloading, the three applications (PCA / LR / LSA), the baselines
//!   (Paillier HE-SVD, DP FedPCA, WDA-PCA, SGD-LR) and the ICA attack.
//! * **Layer 2** — `python/compile/model.py`: JAX compute graphs (masking,
//!   Gram/subspace-iteration steps) lowered once to HLO text.
//! * **Layer 1** — `python/compile/kernels/*.py`: Pallas tile kernels called
//!   from Layer 2; correctness pinned against a pure-jnp oracle.
//!
//! The Rust binary is self-contained after `make artifacts`: Python never
//! runs on the request path. AOT artifacts are loaded through
//! [`runtime::PjrtEngine`] (PJRT CPU client from the `xla` crate).

pub mod util;

// Substrates (bottom-up)
pub mod rng;
pub mod linalg;
pub mod bignum;
pub mod paillier;
pub mod net;
pub mod storage;
pub mod secagg;

// Core library
pub mod mask;
pub mod protocol;
pub mod runtime;
pub mod coordinator;

// Applications & evaluation
pub mod apps;
pub mod baselines;
pub mod attack;
pub mod data;
pub mod metrics;
pub mod config;
pub mod bench;
