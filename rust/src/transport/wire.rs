//! Versioned, length-prefixed little-endian binary codec for every
//! cluster message.
//!
//! This is the byte layer the multi-process deployment speaks: each
//! protocol message travels as one **frame**
//!
//! ```text
//! magic   u32   0xFED5_F4A3
//! version u16   WIRE_VERSION
//! kind    u16   message discriminant (ClusterMsg::kind)
//! label   u64   round label (cluster::labels) for traffic attribution
//! seq     u64   per-peer delivery sequence (0 = unsequenced control)
//! len     u64   payload byte length
//! payload [u8; len]
//! ```
//!
//! The `seq` field (new in v3) is what makes a dropped socket
//! survivable: the sender numbers every protocol frame per peer
//! (1, 2, 3, …), retains frames until the receiver's round
//! acknowledgement retires them, and after a reconnect replays exactly
//! the suffix the receiver reports undelivered. The receiver discards
//! any frame whose `seq` it has already delivered, so a replay can
//! never double-deliver. Control frames (`Abort`/`Shutdown`/
//! `Heartbeat`) carry `seq = 0`: they are never buffered, never
//! replayed, never deduplicated.
//!
//! Everything is little-endian. Floats travel as their raw IEEE-754 bit
//! pattern (`f64::to_bits`/`from_bits`), so ±0, subnormals and NaN
//! payloads round-trip **bit-exactly** — the codec can never be the
//! place where the paper's losslessness guarantee leaks. Decoding is
//! strict: truncated payloads, trailing bytes, oversized length
//! prefixes, unknown kinds and version mismatches are all hard errors
//! (`tests/wire_codec.rs` pins each rejection path).
//!
//! The same [`ClusterMsg`] enum is what the in-process runtime posts
//! through its mailboxes — [`ClusterMsg::sim_wire_bytes`] preserves the
//! simulated-network accounting of the pre-transport runtime (seed
//! deliveries as O(1), secagg shares as 16-byte codewords, …), while
//! the TCP transport meters the *encoded frame length*, i.e. real bytes
//! on the wire.

use crate::bignum::BigUint;
use crate::linalg::Mat;
use crate::mask::block_diag::{BlockDiagSlice, SlicePiece};
use crate::mask::delivery::SeedDelivery;
use crate::net::link::PartyId;
use crate::util::{Error, Result};

/// Frame marker, first 4 bytes of every frame.
pub const FRAME_MAGIC: u32 = 0xFED5_F4A3;
/// Codec version carried by every frame; bump on any layout change
/// (v2: added the `DataMeta` partition-attestation message; v3: added
/// the per-peer `seq` header field, the `Heartbeat` control message and
/// the resume handshake — see [`crate::transport::TcpTransport`]).
pub const WIRE_VERSION: u16 = 3;
/// Fixed frame-header size in bytes
/// (magic + version + kind + label + seq + len).
pub const FRAME_HEADER_LEN: usize = 32;
/// Upper bound on a single frame's payload — anything larger is a
/// corrupt or hostile length prefix, rejected before allocation.
pub const MAX_FRAME_PAYLOAD: u64 = 1 << 32;

/// DH public key wire size (1536-bit MODP group element) — the
/// simulated-metering size of a `Pk`/`PkList` entry.
pub const PK_BYTES: u64 = 1536 / 8;

fn codec(msg: impl std::fmt::Display) -> Error {
    Error::Protocol(format!("wire codec: {msg}"))
}

// ---------------------------------------------------------------------------
// primitive reader
// ---------------------------------------------------------------------------

/// Bounds-checked little-endian cursor over one frame payload.
pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if n > self.remaining() {
            return Err(codec(format!(
                "truncated payload: need {n} bytes, {} left",
                self.remaining()
            )));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().expect("len 2")))
    }

    pub fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().expect("len 4")))
    }

    pub fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().expect("len 8")))
    }

    pub fn u128(&mut self) -> Result<u128> {
        Ok(u128::from_le_bytes(self.take(16)?.try_into().expect("len 16")))
    }

    /// A `usize` encoded as u64 (error on 32-bit overflow).
    pub fn len(&mut self) -> Result<usize> {
        usize::try_from(self.u64()?).map_err(|_| codec("length exceeds usize"))
    }

    /// An element count whose `count * elem_bytes` payload must still fit
    /// in the remaining buffer — checked *before* any allocation, so a
    /// hostile length prefix cannot trigger an OOM.
    pub fn counted(&mut self, elem_bytes: usize) -> Result<usize> {
        let n = self.len()?;
        match n.checked_mul(elem_bytes) {
            Some(total) if total <= self.remaining() => Ok(n),
            _ => Err(codec(format!(
                "length prefix {n} × {elem_bytes} B overruns payload ({} left)",
                self.remaining()
            ))),
        }
    }

    /// An f64 as its raw bit pattern — bit-exact for ±0/subnormal/NaN.
    pub fn f64_bits(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }

    pub fn bytes(&mut self, n: usize) -> Result<&'a [u8]> {
        self.take(n)
    }

    /// Assert the payload was consumed exactly (oversized frames are
    /// rejected, not silently ignored).
    pub fn finish(self) -> Result<()> {
        if self.remaining() > 0 {
            return Err(codec(format!(
                "{} trailing bytes after payload",
                self.remaining()
            )));
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// encode/decode traits + impls for the payload building blocks
// ---------------------------------------------------------------------------

/// Append this value's little-endian wire form to `out`.
pub trait WireEncode {
    fn encode(&self, out: &mut Vec<u8>);
}

/// Parse one value from a [`Reader`] (strict: every byte checked).
pub trait WireDecode: Sized {
    fn decode(r: &mut Reader<'_>) -> Result<Self>;
}

impl WireEncode for u64 {
    fn encode(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_le_bytes());
    }
}

impl WireDecode for u64 {
    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        r.u64()
    }
}

impl WireEncode for f64 {
    fn encode(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_bits().to_le_bytes());
    }
}

impl WireDecode for f64 {
    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        r.f64_bits()
    }
}

impl WireEncode for Vec<f64> {
    fn encode(&self, out: &mut Vec<u8>) {
        (self.len() as u64).encode(out);
        for v in self {
            v.encode(out);
        }
    }
}

impl WireDecode for Vec<f64> {
    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        let n = r.counted(8)?;
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(r.f64_bits()?);
        }
        Ok(v)
    }
}

impl WireEncode for Vec<u128> {
    fn encode(&self, out: &mut Vec<u8>) {
        (self.len() as u64).encode(out);
        for v in self {
            out.extend_from_slice(&v.to_le_bytes());
        }
    }
}

impl WireDecode for Vec<u128> {
    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        let n = r.counted(16)?;
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(r.u128()?);
        }
        Ok(v)
    }
}

impl WireEncode for String {
    fn encode(&self, out: &mut Vec<u8>) {
        (self.len() as u64).encode(out);
        out.extend_from_slice(self.as_bytes());
    }
}

impl WireDecode for String {
    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        let n = r.counted(1)?;
        let b = r.bytes(n)?;
        String::from_utf8(b.to_vec()).map_err(|_| codec("string is not UTF-8"))
    }
}

impl WireEncode for BigUint {
    fn encode(&self, out: &mut Vec<u8>) {
        let b = self.to_bytes_le();
        (b.len() as u64).encode(out);
        out.extend_from_slice(&b);
    }
}

impl WireDecode for BigUint {
    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        let n = r.counted(1)?;
        Ok(BigUint::from_bytes_le(r.bytes(n)?))
    }
}

impl WireEncode for Mat {
    fn encode(&self, out: &mut Vec<u8>) {
        (self.rows() as u64).encode(out);
        (self.cols() as u64).encode(out);
        for v in self.data() {
            v.encode(out);
        }
    }
}

impl WireDecode for Mat {
    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        let rows = r.len()?;
        let cols = r.len()?;
        let n = rows
            .checked_mul(cols)
            .ok_or_else(|| codec("matrix dims overflow"))?;
        if n.checked_mul(8).is_none_or(|b| b > r.remaining()) {
            return Err(codec(format!(
                "matrix {rows}×{cols} overruns payload ({} bytes left)",
                r.remaining()
            )));
        }
        let mut data = Vec::with_capacity(n);
        for _ in 0..n {
            data.push(r.f64_bits()?);
        }
        Mat::from_vec(rows, cols, data)
    }
}

impl WireEncode for SeedDelivery {
    fn encode(&self, out: &mut Vec<u8>) {
        self.seed.encode(out);
        (self.dim as u64).encode(out);
        (self.block as u64).encode(out);
    }
}

impl WireDecode for SeedDelivery {
    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        Ok(SeedDelivery {
            seed: r.u64()?,
            dim: r.len()?,
            block: r.len()?,
        })
    }
}

impl WireEncode for BlockDiagSlice {
    fn encode(&self, out: &mut Vec<u8>) {
        (self.rows() as u64).encode(out);
        (self.cols() as u64).encode(out);
        (self.pieces().len() as u64).encode(out);
        for p in self.pieces() {
            (p.local_row as u64).encode(out);
            (p.global_col as u64).encode(out);
            p.mat.encode(out);
        }
    }
}

impl WireDecode for BlockDiagSlice {
    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        let rows = r.len()?;
        let cols = r.len()?;
        // a piece is ≥ 24 B on the wire (row + col + empty matrix header)
        let n = r.counted(24)?;
        let mut pieces = Vec::with_capacity(n);
        for _ in 0..n {
            let local_row = r.len()?;
            let global_col = r.len()?;
            let mat = Mat::decode(r)?;
            pieces.push(SlicePiece {
                local_row,
                global_col,
                mat,
            });
        }
        BlockDiagSlice::from_pieces(rows, cols, pieces)
    }
}

// ---------------------------------------------------------------------------
// the cluster message set
// ---------------------------------------------------------------------------

/// Every message the cluster protocol exchanges — what the mailboxes
/// carry in-process and what [`encode_frame`] puts on a TCP wire.
///
/// Variants mirror the paper's rounds: mask deliveries (Step 1), secagg
/// key agreement + sharded uploads (Step 2), streamed `U'` blocks and
/// the Σ broadcast (Step 3→4), the blinded V recovery (Step 4), the LR
/// application rounds (`y'` up, `w'` down, partial predictions), and
/// two control frames ([`ClusterMsg::Abort`]/[`ClusterMsg::Shutdown`])
/// for failure propagation and clean connection teardown.
pub enum ClusterMsg {
    /// TA → users: the P mask as a seed (Step 1).
    PSeed(SeedDelivery),
    /// TA → user i: its `Qᵢ` row slice (Step 1).
    QSlice(BlockDiagSlice),
    /// User → CSP: DH public key for secagg (Step 2).
    Pk { user: usize, public: BigUint },
    /// CSP → users: the assembled public-key bulletin board (Step 2).
    PkList(Vec<BigUint>),
    /// User → CSP: one secagg-masked row-shard share (Step 2).
    Batch {
        batch: usize,
        user: usize,
        share: Vec<u128>,
    },
    /// CSP → users: one streamed `U'` row block (Step 3).
    UBlock { r0: usize, data: Mat },
    /// CSP → users: Σ broadcast (Step 4).
    Sigma(Vec<f64>),
    /// User i → CSP: blinded `Qᵢᵀ·Rᵢ` for the V recovery (Step 4).
    VReq { user: usize, blinded: BlockDiagSlice },
    /// CSP → user i: blinded `Vᵢᵀ` response (Step 4).
    VResp(Mat),
    /// LR: label owner → CSP, the masked label vector `y' = P·y`.
    YMasked(Vec<f64>),
    /// LR: CSP → users, the masked coefficients `w' = V'·Σ⁺·U'ᵀ·y'`.
    WMasked(Vec<f64>),
    /// LR: non-owner user → label owner, partial predictions `Xᵢ·wᵢ`.
    /// Tagged with the sender so the owner folds in user order — FP
    /// addition is not associative, and arrival order is thread timing.
    Pred { user: usize, pred: Vec<f64> },
    /// User → TA: partition attestation of a manifest-backed run — the
    /// shape and checksum of the file this user actually opened. The TA
    /// verifies every attestation against the federation manifest before
    /// releasing the Step-1 mask seeds.
    DataMeta {
        user: usize,
        rows: u64,
        cols: u64,
        checksum: u64,
    },
    /// Control: a party failed; peers must error out instead of hanging.
    Abort { from: PartyId, reason: String },
    /// Control: clean connection teardown — the sender is done sending
    /// on this link (distinguishes a finished peer from a crashed one).
    Shutdown { from: PartyId },
    /// Control: link keep-alive (v3). The TCP transport emits these on
    /// otherwise-idle outbound connections so a receiver's idle read
    /// deadline (`FEDSVD_IDLE_TIMEOUT_S`) only ever fires on a peer
    /// that is genuinely gone (crashed or half-open), never on a
    /// healthy federation stuck in a long compute phase. Discarded on
    /// receipt; ledgered under `UNLABELLED` like every control frame.
    Heartbeat { from: PartyId },
}

impl ClusterMsg {
    /// Wire discriminant (frame-header `kind`).
    pub fn kind(&self) -> u16 {
        match self {
            ClusterMsg::PSeed(_) => 0,
            ClusterMsg::QSlice(_) => 1,
            ClusterMsg::Pk { .. } => 2,
            ClusterMsg::PkList(_) => 3,
            ClusterMsg::Batch { .. } => 4,
            ClusterMsg::UBlock { .. } => 5,
            ClusterMsg::Sigma(_) => 6,
            ClusterMsg::VReq { .. } => 7,
            ClusterMsg::VResp(_) => 8,
            ClusterMsg::YMasked(_) => 9,
            ClusterMsg::WMasked(_) => 10,
            ClusterMsg::Pred { .. } => 11,
            ClusterMsg::Abort { .. } => 12,
            ClusterMsg::Shutdown { .. } => 13,
            ClusterMsg::DataMeta { .. } => 14,
            ClusterMsg::Heartbeat { .. } => 15,
        }
    }

    /// Human-readable kind (error messages, logs).
    pub fn kind_name(&self) -> &'static str {
        match self {
            ClusterMsg::PSeed(_) => "PSeed",
            ClusterMsg::QSlice(_) => "QSlice",
            ClusterMsg::Pk { .. } => "Pk",
            ClusterMsg::PkList(_) => "PkList",
            ClusterMsg::Batch { .. } => "Batch",
            ClusterMsg::UBlock { .. } => "UBlock",
            ClusterMsg::Sigma(_) => "Sigma",
            ClusterMsg::VReq { .. } => "VReq",
            ClusterMsg::VResp(_) => "VResp",
            ClusterMsg::YMasked(_) => "YMasked",
            ClusterMsg::WMasked(_) => "WMasked",
            ClusterMsg::Pred { .. } => "Pred",
            ClusterMsg::Abort { .. } => "Abort",
            ClusterMsg::Shutdown { .. } => "Shutdown",
            ClusterMsg::DataMeta { .. } => "DataMeta",
            ClusterMsg::Heartbeat { .. } => "Heartbeat",
        }
    }

    /// The byte size the *simulated* network charges for this message —
    /// exactly the pre-transport runtime's accounting, so
    /// `LocalTransport` keeps every `NetSim` meter and per-label traffic
    /// pin bit-identical (seed deliveries O(1), Q slices as non-zero
    /// payload + 24 B/piece headers, DH keys at the MODP group size,
    /// secagg shares as 16-byte codewords, dense payloads at 8 B/f64).
    pub fn sim_wire_bytes(&self) -> u64 {
        match self {
            ClusterMsg::PSeed(d) => d.wire_bytes(),
            ClusterMsg::QSlice(s) => s.payload_bytes() + (s.pieces().len() as u64) * 24,
            ClusterMsg::Pk { .. } => PK_BYTES,
            ClusterMsg::PkList(v) => PK_BYTES * v.len() as u64,
            ClusterMsg::Batch { share, .. } => (share.len() * 16) as u64,
            ClusterMsg::UBlock { data, .. } => (data.rows() * data.cols() * 8) as u64,
            ClusterMsg::Sigma(s) => (s.len() * 8) as u64,
            ClusterMsg::VReq { blinded, .. } => blinded.payload_bytes(),
            ClusterMsg::VResp(m) => (m.rows() * m.cols() * 8) as u64,
            ClusterMsg::YMasked(y) => (y.len() * 8) as u64,
            ClusterMsg::WMasked(w) => (w.len() * 8) as u64,
            ClusterMsg::Pred { pred, .. } => (pred.len() * 8) as u64,
            ClusterMsg::Abort { reason, .. } => 16 + reason.len() as u64,
            ClusterMsg::Shutdown { .. } => 8,
            ClusterMsg::DataMeta { .. } => 32,
            ClusterMsg::Heartbeat { .. } => 8,
        }
    }

    fn encode_payload(&self, out: &mut Vec<u8>) {
        match self {
            ClusterMsg::PSeed(d) => d.encode(out),
            ClusterMsg::QSlice(s) => s.encode(out),
            ClusterMsg::Pk { user, public } => {
                (*user as u64).encode(out);
                public.encode(out);
            }
            ClusterMsg::PkList(v) => {
                (v.len() as u64).encode(out);
                for pk in v {
                    pk.encode(out);
                }
            }
            ClusterMsg::Batch { batch, user, share } => {
                (*batch as u64).encode(out);
                (*user as u64).encode(out);
                share.encode(out);
            }
            ClusterMsg::UBlock { r0, data } => {
                (*r0 as u64).encode(out);
                data.encode(out);
            }
            ClusterMsg::Sigma(s) => s.encode(out),
            ClusterMsg::VReq { user, blinded } => {
                (*user as u64).encode(out);
                blinded.encode(out);
            }
            ClusterMsg::VResp(m) => m.encode(out),
            ClusterMsg::YMasked(y) => y.encode(out),
            ClusterMsg::WMasked(w) => w.encode(out),
            ClusterMsg::Pred { user, pred } => {
                (*user as u64).encode(out);
                pred.encode(out);
            }
            ClusterMsg::Abort { from, reason } => {
                (*from as u64).encode(out);
                reason.encode(out);
            }
            ClusterMsg::Shutdown { from } => (*from as u64).encode(out),
            ClusterMsg::Heartbeat { from } => (*from as u64).encode(out),
            ClusterMsg::DataMeta {
                user,
                rows,
                cols,
                checksum,
            } => {
                (*user as u64).encode(out);
                rows.encode(out);
                cols.encode(out);
                checksum.encode(out);
            }
        }
    }

    fn decode_payload(kind: u16, payload: &[u8]) -> Result<ClusterMsg> {
        let mut r = Reader::new(payload);
        let msg = match kind {
            0 => ClusterMsg::PSeed(SeedDelivery::decode(&mut r)?),
            1 => ClusterMsg::QSlice(BlockDiagSlice::decode(&mut r)?),
            2 => ClusterMsg::Pk {
                user: r.len()?,
                public: BigUint::decode(&mut r)?,
            },
            3 => {
                let n = r.counted(8)?;
                let mut v = Vec::with_capacity(n);
                for _ in 0..n {
                    v.push(BigUint::decode(&mut r)?);
                }
                ClusterMsg::PkList(v)
            }
            4 => ClusterMsg::Batch {
                batch: r.len()?,
                user: r.len()?,
                share: Vec::<u128>::decode(&mut r)?,
            },
            5 => ClusterMsg::UBlock {
                r0: r.len()?,
                data: Mat::decode(&mut r)?,
            },
            6 => ClusterMsg::Sigma(Vec::<f64>::decode(&mut r)?),
            7 => ClusterMsg::VReq {
                user: r.len()?,
                blinded: BlockDiagSlice::decode(&mut r)?,
            },
            8 => ClusterMsg::VResp(Mat::decode(&mut r)?),
            9 => ClusterMsg::YMasked(Vec::<f64>::decode(&mut r)?),
            10 => ClusterMsg::WMasked(Vec::<f64>::decode(&mut r)?),
            11 => ClusterMsg::Pred {
                user: r.len()?,
                pred: Vec::<f64>::decode(&mut r)?,
            },
            12 => ClusterMsg::Abort {
                from: r.len()?,
                reason: String::decode(&mut r)?,
            },
            13 => ClusterMsg::Shutdown { from: r.len()? },
            14 => ClusterMsg::DataMeta {
                user: r.len()?,
                rows: r.u64()?,
                cols: r.u64()?,
                checksum: r.u64()?,
            },
            15 => ClusterMsg::Heartbeat { from: r.len()? },
            other => return Err(codec(format!("unknown message kind {other}"))),
        };
        r.finish()?;
        Ok(msg)
    }
}

// ---------------------------------------------------------------------------
// frames
// ---------------------------------------------------------------------------

/// Encode `msg` as one complete frame tagged with round `label` and
/// per-peer delivery sequence `seq` (0 for unsequenced control frames).
pub fn encode_frame(msg: &ClusterMsg, label: u64, seq: u64) -> Vec<u8> {
    let mut out = Vec::with_capacity(FRAME_HEADER_LEN + 64);
    out.extend_from_slice(&FRAME_MAGIC.to_le_bytes());
    out.extend_from_slice(&WIRE_VERSION.to_le_bytes());
    out.extend_from_slice(&msg.kind().to_le_bytes());
    out.extend_from_slice(&label.to_le_bytes());
    out.extend_from_slice(&seq.to_le_bytes());
    out.extend_from_slice(&0u64.to_le_bytes()); // len, patched below
    msg.encode_payload(&mut out);
    let plen = (out.len() - FRAME_HEADER_LEN) as u64;
    out[24..32].copy_from_slice(&plen.to_le_bytes());
    out
}

/// Parse a frame header, rejecting bad magic, version drift and
/// oversized length prefixes. Returns `(kind, label, seq, payload_len)`.
fn parse_header(hdr: &[u8; FRAME_HEADER_LEN]) -> Result<(u16, u64, u64, u64)> {
    let magic = u32::from_le_bytes(hdr[0..4].try_into().expect("len 4"));
    if magic != FRAME_MAGIC {
        return Err(codec(format!("bad frame magic {magic:#010x}")));
    }
    let version = u16::from_le_bytes(hdr[4..6].try_into().expect("len 2"));
    if version != WIRE_VERSION {
        return Err(codec(format!(
            "protocol version mismatch: frame v{version}, this build v{WIRE_VERSION}"
        )));
    }
    let kind = u16::from_le_bytes(hdr[6..8].try_into().expect("len 2"));
    let label = u64::from_le_bytes(hdr[8..16].try_into().expect("len 8"));
    let seq = u64::from_le_bytes(hdr[16..24].try_into().expect("len 8"));
    let plen = u64::from_le_bytes(hdr[24..32].try_into().expect("len 8"));
    if plen > MAX_FRAME_PAYLOAD {
        return Err(codec(format!(
            "frame payload length {plen} exceeds cap {MAX_FRAME_PAYLOAD}"
        )));
    }
    Ok((kind, label, seq, plen))
}

/// Decode one complete frame from a byte slice. The slice must hold
/// exactly one frame — shorter is "truncated", longer is rejected.
pub fn decode_frame(buf: &[u8]) -> Result<(ClusterMsg, u64, u64)> {
    if buf.len() < FRAME_HEADER_LEN {
        return Err(codec(format!(
            "truncated frame: {} bytes, header needs {FRAME_HEADER_LEN}",
            buf.len()
        )));
    }
    let hdr: &[u8; FRAME_HEADER_LEN] = buf[..FRAME_HEADER_LEN].try_into().expect("header len");
    let (kind, label, seq, plen) = parse_header(hdr)?;
    let body = &buf[FRAME_HEADER_LEN..];
    if (body.len() as u64) < plen {
        return Err(codec(format!(
            "truncated frame: payload {} of {plen} bytes",
            body.len()
        )));
    }
    if (body.len() as u64) > plen {
        return Err(codec(format!(
            "frame longer than its length prefix ({} > {plen})",
            body.len()
        )));
    }
    Ok((ClusterMsg::decode_payload(kind, body)?, label, seq))
}

/// Read one frame from a stream. Returns `(msg, label, seq,
/// wire_bytes)` where `wire_bytes` is the full on-the-wire frame size
/// (header + payload) — the number the real-transport traffic ledger
/// records.
///
/// The payload buffer grows only as bytes actually arrive (bounded
/// initial reservation), so a lying length prefix cannot force a huge
/// allocation without the peer really sending that much data.
pub fn read_frame(rd: &mut impl std::io::Read) -> Result<(ClusterMsg, u64, u64, u64)> {
    let mut hdr = [0u8; FRAME_HEADER_LEN];
    rd.read_exact(&mut hdr)?;
    let (kind, label, seq, plen) = parse_header(&hdr)?;
    let mut payload = Vec::with_capacity(plen.min(1 << 20) as usize);
    let got = rd.by_ref().take(plen).read_to_end(&mut payload)?;
    if got as u64 != plen {
        return Err(codec(format!(
            "truncated frame: stream ended after {got} of {plen} payload bytes"
        )));
    }
    let msg = ClusterMsg::decode_payload(kind, &payload)?;
    Ok((msg, label, seq, (FRAME_HEADER_LEN as u64) + plen))
}

/// Write one frame to a stream; returns the on-the-wire byte count.
pub fn write_frame(
    wr: &mut impl std::io::Write,
    msg: &ClusterMsg,
    label: u64,
    seq: u64,
) -> Result<u64> {
    let buf = encode_frame(msg, label, seq);
    wr.write_all(&buf)?;
    Ok(buf.len() as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_roundtrip_sigma() {
        let msg = ClusterMsg::Sigma(vec![1.5, -0.0, f64::MIN_POSITIVE / 8.0]);
        let buf = encode_frame(&msg, 42, 7);
        let (back, label, seq) = decode_frame(&buf).unwrap();
        assert_eq!(label, 42);
        assert_eq!(seq, 7);
        let ClusterMsg::Sigma(s) = back else {
            panic!("wrong kind")
        };
        assert_eq!(s[0].to_bits(), 1.5f64.to_bits());
        assert_eq!(s[1].to_bits(), (-0.0f64).to_bits());
        assert_eq!(s[2].to_bits(), (f64::MIN_POSITIVE / 8.0).to_bits());
    }

    #[test]
    fn stream_roundtrip_matches_slice_decode() {
        let msg = ClusterMsg::Pred {
            user: 3,
            pred: vec![0.25; 7],
        };
        let buf = encode_frame(&msg, 9, 21);
        let mut cur = std::io::Cursor::new(buf.clone());
        let (back, label, seq, bytes) = read_frame(&mut cur).unwrap();
        assert_eq!(label, 9);
        assert_eq!(seq, 21);
        assert_eq!(bytes, buf.len() as u64);
        assert!(matches!(back, ClusterMsg::Pred { user: 3, .. }));
    }

    #[test]
    fn frame_roundtrip_data_meta() {
        let msg = ClusterMsg::DataMeta {
            user: 2,
            rows: 48,
            cols: 9,
            checksum: 0xdead_beef_cafe_f00d,
        };
        let buf = encode_frame(&msg, 4, 1);
        let (back, label, _) = decode_frame(&buf).unwrap();
        assert_eq!(label, 4);
        let ClusterMsg::DataMeta {
            user,
            rows,
            cols,
            checksum,
        } = back
        else {
            panic!("wrong kind")
        };
        assert_eq!((user, rows, cols, checksum), (2, 48, 9, 0xdead_beef_cafe_f00d));
        assert_eq!(msg.sim_wire_bytes(), 32);
    }

    #[test]
    fn rejects_bad_magic_version_and_oversize() {
        let msg = ClusterMsg::Shutdown { from: 1 };
        let good = encode_frame(&msg, 0, 0);
        let mut bad_magic = good.clone();
        bad_magic[0] ^= 0xff;
        assert!(decode_frame(&bad_magic).is_err());
        let mut bad_version = good.clone();
        bad_version[4] = 0x7f;
        assert!(decode_frame(&bad_version).is_err());
        let mut bad_len = good.clone();
        bad_len[24..32].copy_from_slice(&(MAX_FRAME_PAYLOAD + 1).to_le_bytes());
        assert!(decode_frame(&bad_len).is_err());
        // every strict prefix is truncated
        for cut in 0..good.len() {
            assert!(decode_frame(&good[..cut]).is_err(), "prefix {cut}");
        }
    }
}
