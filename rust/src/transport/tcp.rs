//! Real-socket transport on `std::net` (zero new dependencies).
//!
//! Each party binds one listener and keeps one lazily-opened outgoing
//! stream per peer it sends to. A connection starts with a 32-byte
//! handshake (magic + codec version + session id + sender/target party
//! ids) answered by an 8-byte ack, then carries [`wire`] frames one
//! after another. Per-connection TCP ordering is exactly the FIFO the
//! protocol needs between any two parties; cross-peer interleaving is
//! handled by the runtime's hold-back queue.
//!
//! Accounting is **real bytes**: every frame (header included) and
//! handshake is added to the endpoint's ledger — sent bytes under the
//! round label open at `send` time, received bytes under the label
//! carried in the frame header, handshakes under the
//! [`crate::cluster::round::UNLABELLED`] sentinel. Merging the *sent*
//! ledgers of all endpoints therefore counts each wire byte exactly
//! once; one endpoint's [`TcpTransport::seen_ledger`] counts everything
//! that crossed its own NIC.
//!
//! Failure model: a party that errors calls [`Transport::abort`], which
//! pushes an `Abort` control frame to every reachable peer before
//! tearing down — peers' `recv`s then error with the originator's
//! reason instead of hanging. A clean [`Transport::close`] sends
//! `Shutdown` frames so readers can tell a finished peer from a crashed
//! one: end-of-stream *without* a preceding `Shutdown` is treated as a
//! lost peer and aborts the local party too.

use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::{Duration, Instant};

use crate::cluster::mailbox::Mailbox;
use crate::cluster::round::UNLABELLED;
use crate::net::link::PartyId;
use crate::util::{Error, Result};

use super::wire::{self, ClusterMsg, WIRE_VERSION};
use super::Transport;

/// First 4 bytes of a connection handshake (distinct from frame magic).
const HELLO_MAGIC: u32 = 0xFED5_4E10;
/// magic u32 + version u16 + pad u16 + session u64 + from u64 + to u64.
const HELLO_LEN: usize = 32;
const ACK_LEN: usize = 8;
/// Handshake ack status codes.
const ACK_OK: u16 = 0;
const ACK_BAD_VERSION: u16 = 2;
const ACK_BAD_SESSION: u16 = 3;
const ACK_BAD_TARGET: u16 = 4;

fn default_secs(env: &str, default: u64) -> Duration {
    let s = std::env::var(env)
        .ok()
        .and_then(|v| v.parse::<u64>().ok())
        .unwrap_or(default);
    Duration::from_secs(s.max(1))
}

/// State shared with the acceptor/reader threads.
struct Shared {
    party: PartyId,
    session: u64,
    inbox: Mailbox<ClusterMsg>,
    /// label → real bytes this endpoint wrote (frames + handshakes).
    sent: Mutex<HashMap<u64, u64>>,
    /// label → real bytes this endpoint read off its socket.
    recvd: Mutex<HashMap<u64, u64>>,
    /// First abort reason seen (local failure or peer `Abort` frame).
    abort_reason: Mutex<Option<String>>,
    /// Completed inbound handshakes per party: lets a reader that saw a
    /// zero-frame EOF tell a client's handshake retry (a newer
    /// connection supersedes this one) from a peer that died right
    /// after connecting.
    handshakes: Mutex<HashMap<PartyId, u64>>,
    shutdown: AtomicBool,
}

impl Shared {
    fn add(map: &Mutex<HashMap<u64, u64>>, label: u64, bytes: u64) {
        *map.lock().expect("ledger poisoned").entry(label).or_insert(0) += bytes;
    }

    fn fail(&self, reason: String) {
        self.abort_reason
            .lock()
            .expect("abort poisoned")
            .get_or_insert(reason);
        self.inbox.close();
    }
}

/// Why one connect+handshake attempt failed: transient I/O (the peer
/// may still be binding — retryable) vs an explicit rejection by a live
/// peer (definitive — retrying can never fix a wrong session/version).
enum HandshakeError {
    Io(std::io::Error),
    Rejected(Error),
}

impl From<std::io::Error> for HandshakeError {
    fn from(e: std::io::Error) -> Self {
        HandshakeError::Io(e)
    }
}

/// One party's real-socket endpoint.
pub struct TcpTransport {
    party: PartyId,
    local_addr: SocketAddr,
    peers: OnceLock<HashMap<PartyId, String>>,
    conns: Mutex<HashMap<PartyId, TcpStream>>,
    open_label: Mutex<Option<u64>>,
    shared: Arc<Shared>,
    connect_timeout: Duration,
    handshake_timeout: Duration,
}

impl TcpTransport {
    /// Bind `listen` (e.g. `127.0.0.1:0` for an ephemeral port) and
    /// start accepting peers of `session`. Peer addresses are supplied
    /// separately via [`TcpTransport::set_peers`] — they are only needed
    /// for *outgoing* connections, and in rendezvous deployments they
    /// are not known until every party has bound.
    ///
    /// Timeouts: `FEDSVD_CONNECT_TIMEOUT_S` bounds how long `send`
    /// retries an unreachable peer (default 20 s — peers may still be
    /// binding), `FEDSVD_HANDSHAKE_TIMEOUT_S` bounds each handshake
    /// read (default 10 s) so a wedged peer fails fast instead of
    /// hanging the federation.
    pub fn bind(listen: &str, party: PartyId, session: u64) -> Result<TcpTransport> {
        let listener = TcpListener::bind(listen)?;
        let local_addr = listener.local_addr()?;
        let shared = Arc::new(Shared {
            party,
            session,
            inbox: Mailbox::new(),
            sent: Mutex::new(HashMap::new()),
            recvd: Mutex::new(HashMap::new()),
            abort_reason: Mutex::new(None),
            handshakes: Mutex::new(HashMap::new()),
            shutdown: AtomicBool::new(false),
        });
        let handshake_timeout = default_secs("FEDSVD_HANDSHAKE_TIMEOUT_S", 10);
        {
            let shared = Arc::clone(&shared);
            std::thread::Builder::new()
                .name(format!("fedsvd-accept-{party}"))
                .spawn(move || accept_loop(listener, shared, handshake_timeout))
                .map_err(|e| Error::Runtime(format!("spawn accept thread: {e}")))?;
        }
        Ok(TcpTransport {
            party,
            local_addr,
            peers: OnceLock::new(),
            conns: Mutex::new(HashMap::new()),
            open_label: Mutex::new(None),
            shared,
            connect_timeout: default_secs("FEDSVD_CONNECT_TIMEOUT_S", 20),
            handshake_timeout,
        })
    }

    /// The address the listener actually bound (resolves `:0` ports).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Supply the peer address book (`PartyId` → `host:port`). Must be
    /// called before the first `send`; may only be called once.
    pub fn set_peers(&self, peers: HashMap<PartyId, String>) -> Result<()> {
        self.peers
            .set(peers)
            .map_err(|_| Error::Runtime("tcp transport: peers already set".into()))
    }

    /// Real bytes this endpoint *wrote*, per round label (sorted).
    /// Summing this ledger across all endpoints counts each wire byte
    /// exactly once.
    pub fn sent_ledger(&self) -> Vec<(u64, u64)> {
        let m = self.shared.sent.lock().expect("ledger poisoned");
        let mut v: Vec<(u64, u64)> = m.iter().map(|(&l, &b)| (l, b)).collect();
        v.sort_unstable();
        v
    }

    /// Real bytes that crossed this endpoint in either direction, per
    /// round label (sorted) — the single-party view `fedsvd serve`
    /// reports as its `ClusterStats::round_traffic`.
    pub fn seen_ledger(&self) -> Vec<(u64, u64)> {
        let mut merged: HashMap<u64, u64> = self
            .shared
            .sent
            .lock()
            .expect("ledger poisoned")
            .clone();
        for (&l, &b) in self.shared.recvd.lock().expect("ledger poisoned").iter() {
            *merged.entry(l).or_insert(0) += b;
        }
        let mut v: Vec<(u64, u64)> = merged.into_iter().collect();
        v.sort_unstable();
        v
    }

    /// Total real bytes seen by this endpoint (sent + received).
    pub fn total_bytes(&self) -> u64 {
        self.seen_ledger().iter().map(|&(_, b)| b).sum()
    }

    fn addr_of(&self, to: PartyId) -> Result<String> {
        let peers = self
            .peers
            .get()
            .ok_or_else(|| Error::Runtime("tcp transport: peers not set".into()))?;
        peers
            .get(&to)
            .cloned()
            .ok_or_else(|| Error::Runtime(format!("tcp transport: no address for party {to}")))
    }

    /// Connect + handshake to `to` with bounded retry and exponential
    /// backoff, covering the whole startup race window: a refused
    /// connect (the peer has not bound its listener yet), a connection
    /// reset during the hello, and a dropped ack are all *transient* —
    /// `fedsvd serve` processes launch in arbitrary order, so the first
    /// attempt failing must not abort the federation. Only an explicit
    /// protocol rejection (wrong version/session/target, which retrying
    /// can never fix) or the deadline expiring fails the call.
    fn connect_peer(&self, to: PartyId, deadline: Duration) -> Result<TcpStream> {
        let addr = self.addr_of(to)?;
        let t0 = Instant::now();
        let mut backoff = Duration::from_millis(20);
        loop {
            match self.try_connect_handshake(to, &addr) {
                Ok(stream) => return Ok(stream),
                // a rejection is definitive: the peer is alive and said no
                Err(HandshakeError::Rejected(e)) => return Err(e),
                Err(HandshakeError::Io(e)) => {
                    if t0.elapsed() >= deadline {
                        return Err(Error::Runtime(format!(
                            "tcp transport: party {to} unreachable at {addr} after \
                             {:.1}s of retries: {e}",
                            t0.elapsed().as_secs_f64()
                        )));
                    }
                    std::thread::sleep(backoff);
                    // exponential backoff, capped: fast during the launch
                    // race, gentle on a peer that is genuinely slow to bind
                    backoff = (backoff * 2).min(Duration::from_millis(500));
                }
            }
        }
    }

    /// One connect + handshake attempt (see [`TcpTransport::connect_peer`]
    /// for the retry policy around it).
    fn try_connect_handshake(
        &self,
        to: PartyId,
        addr: &str,
    ) -> std::result::Result<TcpStream, HandshakeError> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        stream.set_read_timeout(Some(self.handshake_timeout))?;
        // HELLO: magic, version, pad, session, from, to
        let mut hello = Vec::with_capacity(HELLO_LEN);
        hello.extend_from_slice(&HELLO_MAGIC.to_le_bytes());
        hello.extend_from_slice(&WIRE_VERSION.to_le_bytes());
        hello.extend_from_slice(&0u16.to_le_bytes());
        hello.extend_from_slice(&self.shared.session.to_le_bytes());
        hello.extend_from_slice(&(self.party as u64).to_le_bytes());
        hello.extend_from_slice(&(to as u64).to_le_bytes());
        (&stream).write_all(&hello)?;
        Shared::add(&self.shared.sent, UNLABELLED, HELLO_LEN as u64);
        let mut ack = [0u8; ACK_LEN];
        (&stream).read_exact(&mut ack)?;
        Shared::add(&self.shared.recvd, UNLABELLED, ACK_LEN as u64);
        let magic = u32::from_le_bytes(ack[0..4].try_into().expect("len 4"));
        let status = u16::from_le_bytes(ack[6..8].try_into().expect("len 2"));
        if magic != HELLO_MAGIC || status != ACK_OK {
            return Err(HandshakeError::Rejected(Error::Protocol(format!(
                "tcp transport: party {to} rejected handshake (status {status}: {})",
                match status {
                    ACK_BAD_VERSION => "protocol version mismatch",
                    ACK_BAD_SESSION => "wrong session id",
                    ACK_BAD_TARGET => "connected to the wrong party",
                    _ => "malformed ack",
                }
            ))));
        }
        stream.set_read_timeout(None)?;
        Ok(stream)
    }

    /// Write one frame to `to` (opening the connection on first use),
    /// recording real bytes under `label`.
    fn write_to(&self, to: PartyId, msg: &ClusterMsg, label: u64) -> Result<u64> {
        let mut conns = self.conns.lock().expect("conns poisoned");
        if let std::collections::hash_map::Entry::Vacant(e) = conns.entry(to) {
            e.insert(self.connect_peer(to, self.connect_timeout)?);
        }
        let stream = conns.get_mut(&to).expect("just inserted");
        match wire::write_frame(stream, msg, label) {
            Ok(bytes) => {
                Shared::add(&self.shared.sent, label, bytes);
                Ok(bytes)
            }
            Err(e) => {
                // a broken pipe here means the peer died mid-protocol
                conns.remove(&to);
                Err(Error::Runtime(format!(
                    "tcp transport: send to party {to} failed: {e}"
                )))
            }
        }
    }

    fn teardown(&self, notify: Option<&ClusterMsg>) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        let mut conns = self.conns.lock().expect("conns poisoned");
        for (_, stream) in conns.iter_mut() {
            if let Some(msg) = notify {
                let _ = stream.set_write_timeout(Some(Duration::from_secs(2)));
                if let Ok(b) = wire::write_frame(stream, msg, UNLABELLED) {
                    Shared::add(&self.shared.sent, UNLABELLED, b);
                }
            }
            let _ = stream.shutdown(std::net::Shutdown::Both);
        }
        conns.clear();
        drop(conns);
        self.shared.inbox.close();
        // wake the accept loop so it observes the shutdown flag
        let _ = TcpStream::connect(self.local_addr);
    }
}

impl Transport for TcpTransport {
    fn party(&self) -> PartyId {
        self.party
    }

    fn round_enter(&self, label: u64, _senders: usize) -> Result<()> {
        // no cross-process rendezvous: real sockets impose no global
        // round ordering; the label is recorded for traffic attribution
        let mut open = self.open_label.lock().expect("label poisoned");
        *open = Some(label);
        Ok(())
    }

    fn session(&self) -> u64 {
        self.shared.session
    }

    fn send(&self, to: PartyId, msg: ClusterMsg) -> Result<u64> {
        if self.shared.shutdown.load(Ordering::SeqCst) {
            return Err(Error::Runtime("tcp transport: endpoint is shut down".into()));
        }
        let label = self
            .open_label
            .lock()
            .expect("label poisoned")
            .unwrap_or(UNLABELLED);
        self.write_to(to, &msg, label)
    }

    fn round_leave(&self, label: u64) -> Result<()> {
        let mut open = self.open_label.lock().expect("label poisoned");
        if *open != Some(label) {
            return Err(Error::Runtime(format!(
                "tcp transport: leave({label}) without matching enter (open: {:?})",
                *open
            )));
        }
        *open = None;
        Ok(())
    }

    fn recv(&self) -> Result<ClusterMsg> {
        self.shared.inbox.recv().map_err(|e| {
            match self
                .shared
                .abort_reason
                .lock()
                .expect("abort poisoned")
                .as_ref()
            {
                Some(r) => Error::Runtime(format!("federation aborted: {r}")),
                None => e,
            }
        })
    }

    fn meters(&self) -> (f64, u64) {
        (0.0, self.total_bytes())
    }

    fn abort(&self, reason: &str) {
        self.shared
            .fail(format!("party {} failed: {reason}", self.party));
        // best effort: reach every peer in the address book, including
        // ones we never sent to (they may be blocked waiting on us)
        let notify = ClusterMsg::Abort {
            from: self.party,
            reason: reason.to_string(),
        };
        if let Some(peers) = self.peers.get() {
            let already: Vec<PartyId> = self
                .conns
                .lock()
                .expect("conns poisoned")
                .keys()
                .cloned()
                .collect();
            for &pid in peers.keys() {
                if pid == self.party || already.contains(&pid) {
                    continue;
                }
                if let Ok(mut s) = self.connect_peer(pid, Duration::from_secs(2)) {
                    let _ = s.set_write_timeout(Some(Duration::from_secs(2)));
                    if let Ok(b) = wire::write_frame(&mut s, &notify, UNLABELLED) {
                        Shared::add(&self.shared.sent, UNLABELLED, b);
                    }
                }
            }
        }
        self.teardown(Some(&notify));
    }

    fn close(&self) {
        self.teardown(Some(&ClusterMsg::Shutdown { from: self.party }));
    }
}

impl Drop for TcpTransport {
    fn drop(&mut self) {
        if !self.shared.shutdown.load(Ordering::SeqCst) {
            self.teardown(None);
        }
    }
}

// ---------------------------------------------------------------------------
// acceptor side
// ---------------------------------------------------------------------------

fn accept_loop(listener: TcpListener, shared: Arc<Shared>, handshake_timeout: Duration) {
    loop {
        let conn = listener.accept();
        if shared.shutdown.load(Ordering::SeqCst) {
            return;
        }
        let Ok((stream, _)) = conn else { continue };
        let shared = Arc::clone(&shared);
        let _ = std::thread::Builder::new()
            .name(format!("fedsvd-reader-{}", shared.party))
            .spawn(move || reader(stream, shared, handshake_timeout));
    }
}

/// Validate one inbound handshake; answer with an ack. Returns the
/// connecting party's id and this connection's handshake generation
/// (per party, monotonic) when the connection is accepted.
fn handshake_in(
    stream: &mut TcpStream,
    shared: &Shared,
    timeout: Duration,
) -> Result<(PartyId, u64)> {
    stream.set_read_timeout(Some(timeout))?;
    let mut hello = [0u8; HELLO_LEN];
    stream.read_exact(&mut hello)?;
    let magic = u32::from_le_bytes(hello[0..4].try_into().expect("len 4"));
    if magic != HELLO_MAGIC {
        return Err(Error::Protocol("tcp transport: bad hello magic".into()));
    }
    let version = u16::from_le_bytes(hello[4..6].try_into().expect("len 2"));
    let session = u64::from_le_bytes(hello[8..16].try_into().expect("len 8"));
    let from = u64::from_le_bytes(hello[16..24].try_into().expect("len 8")) as PartyId;
    let to = u64::from_le_bytes(hello[24..32].try_into().expect("len 8")) as PartyId;
    let status = if version != WIRE_VERSION {
        ACK_BAD_VERSION
    } else if session != shared.session {
        ACK_BAD_SESSION
    } else if to != shared.party {
        ACK_BAD_TARGET
    } else {
        ACK_OK
    };
    let mut ack = Vec::with_capacity(ACK_LEN);
    ack.extend_from_slice(&HELLO_MAGIC.to_le_bytes());
    ack.extend_from_slice(&WIRE_VERSION.to_le_bytes());
    ack.extend_from_slice(&status.to_le_bytes());
    stream.write_all(&ack)?;
    Shared::add(&shared.sent, UNLABELLED, ACK_LEN as u64);
    if status != ACK_OK {
        return Err(Error::Protocol(format!(
            "tcp transport: rejected inbound handshake (status {status})"
        )));
    }
    Shared::add(&shared.recvd, UNLABELLED, HELLO_LEN as u64);
    stream.set_read_timeout(None)?;
    let gen = {
        let mut h = shared.handshakes.lock().expect("handshakes poisoned");
        let e = h.entry(from).or_insert(0);
        *e += 1;
        *e
    };
    Ok((from, gen))
}

/// Per-connection reader: decode frames and post them to the inbox.
fn reader(mut stream: TcpStream, shared: Arc<Shared>, handshake_timeout: Duration) {
    let (from, my_gen) = match handshake_in(&mut stream, &shared, handshake_timeout) {
        Ok(p) => p,
        Err(_) => return, // rejected or wedged: never part of the session
    };
    let mut frames = 0u64;
    loop {
        match wire::read_frame(&mut stream) {
            Ok((msg, label, bytes)) => {
                frames += 1;
                // every received frame — control frames included — lands
                // in the ledger: seen_ledger really is all NIC traffic
                Shared::add(&shared.recvd, label, bytes);
                match msg {
                    ClusterMsg::Abort { from, reason } => {
                        shared.fail(format!("party {from} aborted: {reason}"));
                        return;
                    }
                    ClusterMsg::Shutdown { .. } => return, // clean end
                    msg => {
                        if shared.inbox.post(msg).is_err() {
                            return; // we are shutting down ourselves
                        }
                    }
                }
            }
            Err(_) => {
                // A stream that dies before carrying a single frame is
                // usually an abandoned handshake attempt: the peer's
                // connect retry (see connect_peer) timed out reading our
                // ack, dropped this connection, and will reconnect —
                // failing immediately would poison a healthy federation.
                // But it could also be a peer that crashed right after
                // connecting, so give the retry a bounded grace window
                // to supersede this connection (a newer handshake from
                // the same party) before declaring the peer lost. A
                // stream that carried real frames and then hit EOF
                // without a Shutdown is a mid-protocol death: fail fast.
                if frames == 0 {
                    let deadline = Instant::now() + Duration::from_secs(2);
                    loop {
                        if shared.shutdown.load(Ordering::SeqCst) {
                            return;
                        }
                        let superseded = shared
                            .handshakes
                            .lock()
                            .expect("handshakes poisoned")
                            .get(&from)
                            .is_some_and(|&g| g > my_gen);
                        if superseded {
                            return; // the retry's connection took over
                        }
                        if Instant::now() >= deadline {
                            break;
                        }
                        std::thread::sleep(Duration::from_millis(25));
                    }
                }
                if !shared.shutdown.load(Ordering::SeqCst) {
                    shared.fail(format!("connection to party {from} lost"));
                }
                return;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::link::{CSP, USER_BASE};

    /// Loopback sockets may be forbidden in exotic sandboxes; skip
    /// rather than fail there (CI runs these for real).
    fn loopback_available() -> bool {
        std::net::TcpListener::bind("127.0.0.1:0").is_ok()
    }

    fn pair(session: u64) -> (TcpTransport, TcpTransport) {
        let a = TcpTransport::bind("127.0.0.1:0", CSP, session).unwrap();
        let b = TcpTransport::bind("127.0.0.1:0", USER_BASE, session).unwrap();
        let addrs: HashMap<PartyId, String> = [
            (CSP, a.local_addr().to_string()),
            (USER_BASE, b.local_addr().to_string()),
        ]
        .into_iter()
        .collect();
        a.set_peers(addrs.clone()).unwrap();
        b.set_peers(addrs).unwrap();
        (a, b)
    }

    #[test]
    fn frames_flow_and_real_bytes_are_ledgered() {
        if !loopback_available() {
            eprintln!("skipping: loopback TCP unavailable");
            return;
        }
        let (csp, user) = pair(11);
        user.round_enter(5, 1).unwrap();
        user.send(CSP, ClusterMsg::Sigma(vec![2.0, -0.0])).unwrap();
        user.round_leave(5).unwrap();
        let ClusterMsg::Sigma(s) = csp.recv().unwrap() else {
            panic!("wrong message")
        };
        assert_eq!(s[0], 2.0);
        assert_eq!(s[1].to_bits(), (-0.0f64).to_bits());
        // 24 B frame header + 8 B count + 16 B payload, plus the 32 B hello
        let sent = user.sent_ledger();
        assert!(sent.contains(&(5, 48)), "sent ledger: {sent:?}");
        assert!(sent.contains(&(UNLABELLED, 32)), "sent ledger: {sent:?}");
        user.close();
        csp.close();
    }

    #[test]
    fn session_mismatch_is_rejected() {
        if !loopback_available() {
            eprintln!("skipping: loopback TCP unavailable");
            return;
        }
        let a = TcpTransport::bind("127.0.0.1:0", CSP, 1).unwrap();
        let b = TcpTransport::bind("127.0.0.1:0", USER_BASE, 2).unwrap();
        let addrs: HashMap<PartyId, String> = [
            (CSP, a.local_addr().to_string()),
            (USER_BASE, b.local_addr().to_string()),
        ]
        .into_iter()
        .collect();
        a.set_peers(addrs.clone()).unwrap();
        b.set_peers(addrs).unwrap();
        let err = b.send(CSP, ClusterMsg::Shutdown { from: USER_BASE });
        assert!(err.is_err());
        a.close();
        b.close();
    }

    #[test]
    fn connect_retries_with_backoff_until_the_peer_binds() {
        if !loopback_available() {
            eprintln!("skipping: loopback TCP unavailable");
            return;
        }
        // reserve an ephemeral port, free it, and bring the peer up late:
        // the first connects are refused, the retry/backoff path must
        // carry the send through once the listener finally binds
        let probe = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = probe.local_addr().unwrap().to_string();
        drop(probe);
        let user = TcpTransport::bind("127.0.0.1:0", USER_BASE, 77).unwrap();
        let addrs: HashMap<PartyId, String> = [
            (CSP, addr.clone()),
            (USER_BASE, user.local_addr().to_string()),
        ]
        .into_iter()
        .collect();
        user.set_peers(addrs).unwrap();
        let late = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(250));
            let csp = TcpTransport::bind(&addr, CSP, 77).unwrap();
            let msg = csp.recv().unwrap();
            assert!(matches!(msg, ClusterMsg::Sigma(_)));
            csp.close();
        });
        user.round_enter(1, 1).unwrap();
        user.send(CSP, ClusterMsg::Sigma(vec![1.0])).unwrap();
        user.round_leave(1).unwrap();
        late.join().unwrap();
        user.close();
    }

    #[test]
    fn abort_frame_fails_the_peer_with_the_reason() {
        if !loopback_available() {
            eprintln!("skipping: loopback TCP unavailable");
            return;
        }
        let (csp, user) = pair(12);
        user.abort("injected failure");
        let err = csp.recv().unwrap_err();
        let text = err.to_string();
        assert!(text.contains("injected failure"), "got: {text}");
        csp.close();
    }
}
