//! Real-socket transport on `std::net` (zero new dependencies).
//!
//! Each party binds one listener and keeps one lazily-opened outgoing
//! stream per peer it sends to. A connection starts with a 40-byte
//! handshake (magic + codec version + flags + session id +
//! sender/target party ids + the sender's highest assigned sequence
//! number) answered by a 16-byte ack that carries the receiver's
//! last-delivered sequence for that sender, then carries [`wire`]
//! frames one after another. Per-connection TCP ordering is exactly the
//! FIFO the protocol needs between any two parties; cross-peer
//! interleaving is handled by the runtime's hold-back queue.
//!
//! **Resume after a dropped socket.** Every protocol frame a party
//! sends is numbered per peer (`seq` = 1, 2, 3, …) and retained in a
//! per-peer replay buffer until the receiver acknowledges it. The
//! receiver pushes tiny acknowledgement records back on the *reverse*
//! direction of the same socket at round-label boundaries; the sender
//! drains them non-blockingly and retires acknowledged frames. When a
//! write hits a dead socket the sender reconnects with capped retries
//! (`FEDSVD_RECONNECT_RETRIES`, reusing the connect/backoff machinery),
//! and because every handshake ack reports the receiver's
//! last-delivered sequence, the sender replays exactly the
//! unacknowledged suffix. The receiver discards any frame whose `seq`
//! it has already delivered, so party bodies in [`crate::cluster`]
//! never observe a duplicate — a severed connection is invisible above
//! the transport. Control frames (`Abort`/`Shutdown`/`Heartbeat`) carry
//! `seq = 0` and are never buffered, replayed or deduplicated.
//!
//! Accounting is **real bytes**: every frame (header included) and
//! handshake is added to the endpoint's ledger — sent bytes under the
//! round label open at `send` time, received bytes under the label
//! carried in the frame header, handshakes/heartbeats/acks under the
//! [`crate::cluster::round::UNLABELLED`] sentinel. *Replayed* frames
//! and *discarded duplicate* frames are metered separately
//! ([`TcpTransport::replayed_bytes`]) and never added to the round
//! ledgers, so merging the *sent* ledgers of all endpoints still counts
//! each protocol byte exactly once even across reconnects.
//!
//! Failure model: a party that errors calls [`Transport::abort`], which
//! pushes an `Abort` control frame to every reachable peer before
//! tearing down — peers' `recv`s then error with the originator's
//! reason instead of hanging. A clean [`Transport::close`] sends
//! `Shutdown` frames so readers can tell a finished peer from a crashed
//! one. End-of-stream *without* a preceding `Shutdown` is recoverable
//! socket death: the reader grants the sender's reconnect a bounded
//! grace window to supersede the connection and only then declares the
//! peer lost. A peer that goes completely silent (half-open socket, no
//! frames and no heartbeats) is declared lost after
//! `FEDSVD_IDLE_TIMEOUT_S` instead of blocking forever.

use std::collections::{HashMap, VecDeque};
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, OnceLock};
use std::time::{Duration, Instant};

use crate::cluster::mailbox::Mailbox;
use crate::cluster::round::UNLABELLED;
use crate::net::link::PartyId;
use crate::obs;
use crate::util::{Error, Result};

use super::wire::{self, ClusterMsg, WIRE_VERSION};
use super::Transport;

/// First 4 bytes of a connection handshake (distinct from frame magic).
const HELLO_MAGIC: u32 = 0xFED5_4E10;
/// magic u32 + version u16 + flags u16 + session u64 + from u64 +
/// to u64 + sent_seq u64.
const HELLO_LEN: usize = 40;
/// magic u32 + version u16 + status u16 + delivered u64.
const ACK_LEN: usize = 16;
/// Hello flag bit 0: the sender has prior outbound state for this peer
/// (informational — every handshake is a potential resume).
const HELLO_FLAG_RESUME: u16 = 1;
/// Handshake ack status codes.
const ACK_OK: u16 = 0;
const ACK_BAD_VERSION: u16 = 2;
const ACK_BAD_SESSION: u16 = 3;
const ACK_BAD_TARGET: u16 = 4;
/// First 4 bytes of a reverse-channel round-acknowledgement record
/// (distinct from both the frame and hello magics).
const ACK_RECORD_MAGIC: u32 = 0xFED5_AC4E;
/// magic u32 + pad u32 + delivered-seq u64.
const ACK_RECORD_LEN: usize = 16;

fn default_secs(env: &str, default: u64) -> Duration {
    let s = std::env::var(env)
        .ok()
        .and_then(|v| v.parse::<u64>().ok())
        .unwrap_or(default);
    Duration::from_secs(s.max(1))
}

/// Poison-recovering lock: a panic in one reader thread must degrade to
/// that single peer failing (and the flight recorder dumping), not
/// cascade `PoisonError` panics through every thread that shares the
/// ledgers. All shared maps here stay internally consistent under
/// panic because each critical section completes its updates or none.
fn lock_ok<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// One frame retained for replay until the receiver acknowledges it.
struct SentFrame {
    seq: u64,
    label: u64,
    bytes: Vec<u8>,
    /// Whether this frame's bytes have been added to the sent ledger
    /// (first successful write). Replays of ledgered frames count
    /// toward the separate `replayed_bytes` meter instead — a frame is
    /// never double-counted no matter how many times it crosses a wire.
    ledgered: bool,
}

/// Per-peer outbound sequencing + replay state.
struct Outbound {
    /// Next sequence number to assign (sequences start at 1; 0 marks
    /// unsequenced control frames).
    next_seq: u64,
    /// Unacknowledged frames, oldest first.
    buf: VecDeque<SentFrame>,
}

impl Outbound {
    fn new() -> Outbound {
        Outbound { next_seq: 1, buf: VecDeque::new() }
    }
}

/// One established outgoing connection.
struct Conn {
    stream: TcpStream,
    /// Partial reverse-channel ack bytes drained off this socket.
    ack_buf: Vec<u8>,
    /// Set once the ack channel mis-frames: stop trusting it (the
    /// replay buffer then only retires on resume handshakes — a memory
    /// bound lost, never correctness).
    acks_dead: bool,
}

impl Conn {
    fn new(stream: TcpStream) -> Conn {
        Conn { stream, ack_buf: Vec::new(), acks_dead: false }
    }
}

/// State shared with the acceptor/reader/heartbeat threads.
struct Shared {
    party: PartyId,
    session: u64,
    inbox: Mailbox<ClusterMsg>,
    /// Established outgoing connections, one per peer.
    conns: Mutex<HashMap<PartyId, Conn>>,
    /// Per-peer outbound sequencing and replay buffers.
    outbound: Mutex<HashMap<PartyId, Outbound>>,
    /// Highest sequence number delivered per *sending* peer — the
    /// receiver-side dedup state a resume handshake reports back.
    delivered: Mutex<HashMap<PartyId, u64>>,
    /// label → real bytes this endpoint wrote (frames + handshakes).
    sent: Mutex<HashMap<u64, u64>>,
    /// label → real bytes this endpoint read off its socket.
    recvd: Mutex<HashMap<u64, u64>>,
    /// First abort reason seen (local failure or peer `Abort` frame).
    abort_reason: Mutex<Option<String>>,
    /// Completed inbound handshakes per party: lets a reader that saw
    /// an EOF tell a peer's reconnect (a newer connection supersedes
    /// this one) from a peer that died for good.
    handshakes: Mutex<HashMap<PartyId, u64>>,
    /// Idle read deadline in ms (atomic so tests can shrink it live).
    idle_timeout_ms: AtomicU64,
    /// Reconnect attempts before a dead socket becomes a lost peer.
    reconnect_retries: AtomicU32,
    /// How long a mid-protocol EOF waits for a superseding reconnect.
    reconnect_grace: Duration,
    /// Successful mid-protocol reconnects (outgoing side).
    reconnects: AtomicU64,
    /// Bytes re-sent from replay buffers (already in the sent ledger).
    replayed_bytes: AtomicU64,
    /// Bytes received and discarded as already-delivered duplicates.
    replay_recvd_bytes: AtomicU64,
    shutdown: AtomicBool,
}

impl Shared {
    fn add(map: &Mutex<HashMap<u64, u64>>, label: u64, bytes: u64) {
        *lock_ok(map).entry(label).or_insert(0) += bytes;
        if label == UNLABELLED {
            // every control byte (handshake, ack, heartbeat, shutdown)
            // in either direction counts toward the live overhead gauge
            crate::obs::metrics_live::on_overhead_bytes(bytes);
        }
    }

    /// Ledger control bytes written by a *background* thread (heartbeat
    /// ticks, ack records) — but not once teardown has begun. Teardown
    /// snapshots the sent-side overhead total into a trace instant, and
    /// that snapshot must be final: the shutdown check happens under
    /// the same lock the snapshot reads, so a best-effort ack racing
    /// the snapshot is either counted by it or not ledgered at all.
    fn add_sent_unless_down(&self, bytes: u64) {
        let mut m = lock_ok(&self.sent);
        if self.shutdown.load(Ordering::SeqCst) {
            return;
        }
        *m.entry(UNLABELLED).or_insert(0) += bytes;
        drop(m);
        crate::obs::metrics_live::on_overhead_bytes(bytes);
    }

    fn fail(&self, reason: String) {
        lock_ok(&self.abort_reason).get_or_insert(reason);
        self.inbox.close();
    }

    fn idle_timeout(&self) -> Duration {
        Duration::from_millis(self.idle_timeout_ms.load(Ordering::Relaxed).max(100))
    }

    /// Drop every buffered frame the receiver has acknowledged.
    fn retire_through(&self, to: PartyId, seq: u64) {
        let mut ob = lock_ok(&self.outbound);
        if let Some(o) = ob.get_mut(&to) {
            while o.buf.front().is_some_and(|f| f.seq <= seq) {
                o.buf.pop_front();
            }
        }
    }

    /// Non-blockingly read any round-acknowledgement records the peer
    /// pushed back on this connection's reverse direction and retire
    /// the replay buffer up to the highest acknowledged sequence. Best
    /// effort: acks only bound replay-buffer memory, never correctness
    /// (a resume handshake retires independently).
    fn drain_acks(&self, to: PartyId, conn: &mut Conn) {
        if conn.acks_dead || conn.stream.set_nonblocking(true).is_err() {
            conn.acks_dead = true;
            return;
        }
        let mut tmp = [0u8; 256];
        loop {
            match conn.stream.read(&mut tmp) {
                Ok(0) => break, // EOF: the write path will notice
                Ok(n) => {
                    conn.ack_buf.extend_from_slice(&tmp[..n]);
                    Shared::add(&self.recvd, UNLABELLED, n as u64);
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(_) => break,
            }
        }
        let _ = conn.stream.set_nonblocking(false);
        let mut acked: Option<u64> = None;
        while conn.ack_buf.len() >= ACK_RECORD_LEN {
            let rec: Vec<u8> = conn.ack_buf.drain(..ACK_RECORD_LEN).collect();
            let magic = u32::from_le_bytes(rec[0..4].try_into().expect("len 4"));
            if magic != ACK_RECORD_MAGIC {
                conn.acks_dead = true;
                conn.ack_buf.clear();
                break;
            }
            let seq = u64::from_le_bytes(rec[8..16].try_into().expect("len 8"));
            acked = Some(acked.map_or(seq, |a| a.max(seq)));
        }
        if let Some(seq) = acked {
            self.retire_through(to, seq);
        }
    }

    /// Ledger `seq`'s bytes under its round label exactly once (first
    /// successful write).
    fn mark_ledgered(&self, to: PartyId, seq: u64, label: u64, n: u64) {
        let mut ob = lock_ok(&self.outbound);
        match ob
            .get_mut(&to)
            .and_then(|o| o.buf.iter_mut().find(|f| f.seq == seq))
        {
            Some(f) if f.ledgered => {}
            Some(f) => {
                f.ledgered = true;
                Shared::add(&self.sent, label, n);
            }
            // already retired by a racing ack: it reached the wire
            None => Shared::add(&self.sent, label, n),
        }
    }
}

/// Why one connect+handshake attempt failed: transient I/O (the peer
/// may still be binding — retryable) vs an explicit rejection by a live
/// peer (definitive — retrying can never fix a wrong session/version).
enum HandshakeError {
    Io(std::io::Error),
    Rejected(Error),
}

impl From<std::io::Error> for HandshakeError {
    fn from(e: std::io::Error) -> Self {
        HandshakeError::Io(e)
    }
}

/// One party's real-socket endpoint.
pub struct TcpTransport {
    party: PartyId,
    local_addr: SocketAddr,
    peers: OnceLock<HashMap<PartyId, String>>,
    open_label: Mutex<Option<u64>>,
    shared: Arc<Shared>,
    connect_timeout: Duration,
    handshake_timeout: Duration,
}

impl TcpTransport {
    /// Bind `listen` (e.g. `127.0.0.1:0` for an ephemeral port) and
    /// start accepting peers of `session`. Peer addresses are supplied
    /// separately via [`TcpTransport::set_peers`] — they are only needed
    /// for *outgoing* connections, and in rendezvous deployments they
    /// are not known until every party has bound.
    ///
    /// Timeouts and retry knobs: `FEDSVD_CONNECT_TIMEOUT_S` bounds how
    /// long `send` retries an unreachable peer (default 20 s — peers
    /// may still be binding), `FEDSVD_HANDSHAKE_TIMEOUT_S` bounds each
    /// handshake read (default 10 s), `FEDSVD_IDLE_TIMEOUT_S` is the
    /// steady-state read/write deadline after which a silent peer is
    /// declared lost (default 300 s; heartbeats flow at a quarter of
    /// it, so only a genuinely dead peer trips it), and
    /// `FEDSVD_RECONNECT_RETRIES` caps mid-protocol reconnect attempts
    /// (default 5, `0` = fail on the first dead write).
    pub fn bind(listen: &str, party: PartyId, session: u64) -> Result<TcpTransport> {
        let listener = TcpListener::bind(listen)?;
        let local_addr = listener.local_addr()?;
        let connect_timeout = default_secs("FEDSVD_CONNECT_TIMEOUT_S", 20);
        let reconnect_retries = std::env::var("FEDSVD_RECONNECT_RETRIES")
            .ok()
            .and_then(|v| v.parse::<u32>().ok())
            .unwrap_or(5);
        let shared = Arc::new(Shared {
            party,
            session,
            inbox: Mailbox::new(),
            conns: Mutex::new(HashMap::new()),
            outbound: Mutex::new(HashMap::new()),
            delivered: Mutex::new(HashMap::new()),
            sent: Mutex::new(HashMap::new()),
            recvd: Mutex::new(HashMap::new()),
            abort_reason: Mutex::new(None),
            handshakes: Mutex::new(HashMap::new()),
            idle_timeout_ms: AtomicU64::new(
                default_secs("FEDSVD_IDLE_TIMEOUT_S", 300).as_millis() as u64,
            ),
            reconnect_retries: AtomicU32::new(reconnect_retries),
            reconnect_grace: connect_timeout,
            reconnects: AtomicU64::new(0),
            replayed_bytes: AtomicU64::new(0),
            replay_recvd_bytes: AtomicU64::new(0),
            shutdown: AtomicBool::new(false),
        });
        let handshake_timeout = default_secs("FEDSVD_HANDSHAKE_TIMEOUT_S", 10);
        {
            let shared = Arc::clone(&shared);
            std::thread::Builder::new()
                .name(format!("fedsvd-accept-{party}"))
                .spawn(move || accept_loop(listener, shared, handshake_timeout))
                .map_err(|e| Error::Runtime(format!("spawn accept thread: {e}")))?;
        }
        {
            let shared = Arc::clone(&shared);
            std::thread::Builder::new()
                .name(format!("fedsvd-heartbeat-{party}"))
                .spawn(move || heartbeat_loop(shared))
                .map_err(|e| Error::Runtime(format!("spawn heartbeat thread: {e}")))?;
        }
        Ok(TcpTransport {
            party,
            local_addr,
            peers: OnceLock::new(),
            open_label: Mutex::new(None),
            shared,
            connect_timeout,
            handshake_timeout,
        })
    }

    /// The address the listener actually bound (resolves `:0` ports).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Supply the peer address book (`PartyId` → `host:port`). Must be
    /// called before the first `send`; may only be called once.
    pub fn set_peers(&self, peers: HashMap<PartyId, String>) -> Result<()> {
        self.peers
            .set(peers)
            .map_err(|_| Error::Runtime("tcp transport: peers already set".into()))
    }

    /// Real bytes this endpoint *wrote*, per round label (sorted).
    /// Summing this ledger across all endpoints counts each wire byte
    /// exactly once (replays are metered separately, never here).
    pub fn sent_ledger(&self) -> Vec<(u64, u64)> {
        let m = lock_ok(&self.shared.sent);
        let mut v: Vec<(u64, u64)> = m.iter().map(|(&l, &b)| (l, b)).collect();
        v.sort_unstable();
        v
    }

    /// Real bytes that crossed this endpoint in either direction, per
    /// round label (sorted) — the single-party view `fedsvd serve`
    /// reports as its `ClusterStats::round_traffic`.
    pub fn seen_ledger(&self) -> Vec<(u64, u64)> {
        let mut merged: HashMap<u64, u64> = lock_ok(&self.shared.sent).clone();
        for (&l, &b) in lock_ok(&self.shared.recvd).iter() {
            *merged.entry(l).or_insert(0) += b;
        }
        let mut v: Vec<(u64, u64)> = merged.into_iter().collect();
        v.sort_unstable();
        v
    }

    /// Total real bytes seen by this endpoint (sent + received).
    pub fn total_bytes(&self) -> u64 {
        self.seen_ledger().iter().map(|&(_, b)| b).sum()
    }

    /// Successful mid-protocol reconnects this endpoint performed.
    pub fn reconnects(&self) -> u64 {
        self.shared.reconnects.load(Ordering::Relaxed)
    }

    /// Bytes re-sent from replay buffers after reconnects. Ledgered
    /// separately from `sent_ledger` — never double-counted there.
    pub fn replayed_bytes(&self) -> u64 {
        self.shared.replayed_bytes.load(Ordering::Relaxed)
    }

    /// Bytes received and discarded as already-delivered duplicates.
    pub fn replayed_recv_bytes(&self) -> u64 {
        self.shared.replay_recvd_bytes.load(Ordering::Relaxed)
    }

    /// Override `FEDSVD_RECONNECT_RETRIES` for this endpoint.
    pub fn set_reconnect_retries(&self, n: u32) {
        self.shared.reconnect_retries.store(n, Ordering::Relaxed);
    }

    /// Override `FEDSVD_IDLE_TIMEOUT_S` for this endpoint (floored at
    /// 100 ms). Takes effect on connections established afterwards and
    /// on the heartbeat cadence within ~50 ms.
    pub fn set_idle_timeout(&self, d: Duration) {
        self.shared
            .idle_timeout_ms
            .store(d.as_millis() as u64, Ordering::Relaxed);
    }

    /// Chaos hook: shut down the established socket to `to` while
    /// keeping all bookkeeping intact — from the transport's point of
    /// view the network silently died mid-protocol. The next write
    /// discovers the corpse and takes the reconnect path. Returns
    /// whether a connection existed.
    pub fn sever_conn(&self, to: PartyId) -> bool {
        let conns = lock_ok(&self.shared.conns);
        match conns.get(&to) {
            Some(c) => {
                let _ = c.stream.shutdown(std::net::Shutdown::Both);
                true
            }
            None => false,
        }
    }

    fn addr_of(&self, to: PartyId) -> Result<String> {
        let peers = self
            .peers
            .get()
            .ok_or_else(|| Error::Runtime("tcp transport: peers not set".into()))?;
        peers
            .get(&to)
            .cloned()
            .ok_or_else(|| Error::Runtime(format!("tcp transport: no address for party {to}")))
    }

    /// Connect + handshake to `to` with bounded retry and exponential
    /// backoff, covering the whole startup race window: a refused
    /// connect (the peer has not bound its listener yet), a connection
    /// reset during the hello, and a dropped ack are all *transient* —
    /// `fedsvd serve` processes launch in arbitrary order, so the first
    /// attempt failing must not abort the federation. Only an explicit
    /// protocol rejection (wrong version/session/target, which retrying
    /// can never fix) or the deadline expiring fails the call. Returns
    /// the stream plus the peer's last-delivered sequence for us.
    fn connect_peer(&self, to: PartyId, deadline: Duration) -> Result<(TcpStream, u64)> {
        let addr = self.addr_of(to)?;
        let t0 = Instant::now();
        let mut backoff = Duration::from_millis(20);
        loop {
            match self.try_connect_handshake(to, &addr) {
                Ok(got) => return Ok(got),
                // a rejection is definitive: the peer is alive and said no
                Err(HandshakeError::Rejected(e)) => return Err(e),
                Err(HandshakeError::Io(e)) => {
                    if t0.elapsed() >= deadline {
                        return Err(Error::Runtime(format!(
                            "tcp transport: party {to} unreachable at {addr} after \
                             {:.1}s of retries: {e}",
                            t0.elapsed().as_secs_f64()
                        )));
                    }
                    std::thread::sleep(backoff);
                    // exponential backoff, capped: fast during the launch
                    // race, gentle on a peer that is genuinely slow to bind
                    backoff = (backoff * 2).min(Duration::from_millis(500));
                }
            }
        }
    }

    /// One connect + handshake attempt (see [`TcpTransport::connect_peer`]
    /// for the retry policy around it). Every handshake is a potential
    /// resume: the ack reports how far the receiver already got.
    fn try_connect_handshake(
        &self,
        to: PartyId,
        addr: &str,
    ) -> std::result::Result<(TcpStream, u64), HandshakeError> {
        let mut stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        stream.set_read_timeout(Some(self.handshake_timeout))?;
        let (sent_seq, resuming) = {
            let ob = lock_ok(&self.shared.outbound);
            match ob.get(&to) {
                Some(o) => (o.next_seq - 1, true),
                None => (0, false),
            }
        };
        // HELLO: magic, version, flags, session, from, to, sent_seq
        let mut hello = Vec::with_capacity(HELLO_LEN);
        hello.extend_from_slice(&HELLO_MAGIC.to_le_bytes());
        hello.extend_from_slice(&WIRE_VERSION.to_le_bytes());
        hello.extend_from_slice(&(if resuming { HELLO_FLAG_RESUME } else { 0u16 }).to_le_bytes());
        hello.extend_from_slice(&self.shared.session.to_le_bytes());
        hello.extend_from_slice(&(self.party as u64).to_le_bytes());
        hello.extend_from_slice(&(to as u64).to_le_bytes());
        hello.extend_from_slice(&sent_seq.to_le_bytes());
        stream.write_all(&hello)?;
        Shared::add(&self.shared.sent, UNLABELLED, HELLO_LEN as u64);
        let mut ack = [0u8; ACK_LEN];
        stream.read_exact(&mut ack)?;
        Shared::add(&self.shared.recvd, UNLABELLED, ACK_LEN as u64);
        let magic = u32::from_le_bytes(ack[0..4].try_into().expect("len 4"));
        let status = u16::from_le_bytes(ack[6..8].try_into().expect("len 2"));
        if magic != HELLO_MAGIC || status != ACK_OK {
            return Err(HandshakeError::Rejected(Error::Protocol(format!(
                "tcp transport: party {to} rejected handshake (status {status}: {})",
                match status {
                    ACK_BAD_VERSION => "protocol version mismatch",
                    ACK_BAD_SESSION => "wrong session id",
                    ACK_BAD_TARGET => "connected to the wrong party",
                    _ => "malformed ack",
                }
            ))));
        }
        let delivered = u64::from_le_bytes(ack[8..16].try_into().expect("len 8"));
        // Steady state: reads on this socket are the non-blocking ack
        // drain only; writes get a bounded deadline so a stalled peer
        // with a full TCP window surfaces as peer loss instead of
        // blocking the sender forever.
        let idle = self.shared.idle_timeout();
        stream.set_read_timeout(Some(idle))?;
        stream.set_write_timeout(Some(idle))?;
        Ok((stream, delivered))
    }

    /// Re-send every buffered frame past `delivered`. Frames already in
    /// the sent ledger count toward the `replayed_bytes` meter instead;
    /// frames whose first write died are ledgered normally now. Returns
    /// the replayed (already-ledgered) byte count.
    fn replay_unacked(&self, to: PartyId, conn: &mut Conn, delivered: u64) -> std::io::Result<u64> {
        let mut ob = lock_ok(&self.shared.outbound);
        let Some(o) = ob.get_mut(&to) else { return Ok(0) };
        let mut replayed = 0u64;
        for f in o.buf.iter_mut() {
            if f.seq <= delivered {
                continue;
            }
            conn.stream.write_all(&f.bytes)?;
            let n = f.bytes.len() as u64;
            if f.ledgered {
                replayed += n;
                self.shared.replayed_bytes.fetch_add(n, Ordering::Relaxed);
            } else {
                f.ledgered = true;
                Shared::add(&self.shared.sent, f.label, n);
            }
        }
        Ok(replayed)
    }

    /// The write path's recovery: the socket to `to` died mid-protocol.
    /// Retry connect + resume-handshake with capped attempts
    /// (`FEDSVD_RECONNECT_RETRIES`) and the same exponential backoff
    /// `connect_peer` uses, then replay the unacknowledged suffix. An
    /// explicit protocol rejection or exhausted retries is definitive
    /// peer loss.
    fn reconnect_and_replay(
        &self,
        conns: &mut HashMap<PartyId, Conn>,
        to: PartyId,
        cause: &str,
    ) -> Result<()> {
        conns.remove(&to);
        let retries = self.shared.reconnect_retries.load(Ordering::Relaxed);
        let addr = self.addr_of(to)?;
        let t0 = Instant::now();
        let mut backoff = Duration::from_millis(20);
        let mut last_err = cause.to_string();
        for attempt in 1..=retries {
            if self.shared.shutdown.load(Ordering::SeqCst) {
                break;
            }
            match self.try_connect_handshake(to, &addr) {
                Ok((stream, delivered)) => {
                    let mut conn = Conn::new(stream);
                    self.shared.retire_through(to, delivered);
                    match self.replay_unacked(to, &mut conn, delivered) {
                        Ok(replayed) => {
                            self.shared.reconnects.fetch_add(1, Ordering::Relaxed);
                            obs::metrics_live::on_reconnect(replayed);
                            obs::with_current(|t| {
                                t.instant(obs::EV_RECONNECT, None);
                                t.instant(obs::EV_REPLAYED_BYTES, Some(replayed));
                            });
                            eprintln!(
                                "tcp transport: party {} reconnected to party {to} \
                                 after {attempt} attempt(s) ({cause}); replayed \
                                 {replayed} bytes",
                                self.party
                            );
                            conns.insert(to, conn);
                            return Ok(());
                        }
                        Err(e) => last_err = format!("replay failed: {e}"),
                    }
                }
                Err(HandshakeError::Rejected(e)) => {
                    return Err(Error::Runtime(format!(
                        "tcp transport: lost connection to party {to} ({cause}); \
                         resume rejected: {e}"
                    )));
                }
                Err(HandshakeError::Io(e)) => last_err = e.to_string(),
            }
            if t0.elapsed() >= self.connect_timeout {
                break;
            }
            std::thread::sleep(backoff);
            backoff = (backoff * 2).min(Duration::from_millis(500));
        }
        Err(Error::Runtime(format!(
            "tcp transport: lost connection to party {to} mid-protocol ({cause}); \
             reconnect failed after {retries} attempt(s): {last_err}"
        )))
    }

    /// Write one protocol frame to `to` (opening the connection on
    /// first use), recording real bytes under `label`. The frame is
    /// sequenced and buffered *before* the first write so a socket that
    /// dies mid-send can never lose it — the reconnect path replays it.
    fn write_to(&self, to: PartyId, msg: &ClusterMsg, label: u64) -> Result<u64> {
        let mut conns = lock_ok(&self.shared.conns);
        if !conns.contains_key(&to) {
            let (stream, delivered) = self.connect_peer(to, self.connect_timeout)?;
            let mut conn = Conn::new(stream);
            self.shared.retire_through(to, delivered);
            // a lazily re-opened connection after an earlier failure
            // may still owe the peer its unacked suffix
            self.replay_unacked(to, &mut conn, delivered)
                .map_err(|e| Error::Runtime(format!("tcp transport: replay to party {to}: {e}")))?;
            conns.insert(to, conn);
        }
        let (seq, frame, write_res) = {
            let conn = conns.get_mut(&to).expect("just ensured");
            self.shared.drain_acks(to, conn);
            let (seq, frame) = {
                let mut ob = lock_ok(&self.shared.outbound);
                let o = ob.entry(to).or_insert_with(Outbound::new);
                let seq = o.next_seq;
                o.next_seq += 1;
                let frame = wire::encode_frame(msg, label, seq);
                o.buf.push_back(SentFrame {
                    seq,
                    label,
                    bytes: frame.clone(),
                    ledgered: false,
                });
                (seq, frame)
            };
            let res = conn.stream.write_all(&frame);
            (seq, frame, res)
        };
        let n = frame.len() as u64;
        match write_res {
            Ok(()) => {
                self.shared.mark_ledgered(to, seq, label, n);
                Ok(n)
            }
            // recoverable socket death: reconnect + replay (the frame
            // just queued rides along) or surface definitive peer loss
            Err(e) => self
                .reconnect_and_replay(&mut conns, to, &e.to_string())
                .map(|()| n),
        }
    }

    fn teardown(&self, notify: Option<&ClusterMsg>) {
        let already_down = self.shared.shutdown.swap(true, Ordering::SeqCst);
        let mut conns = lock_ok(&self.shared.conns);
        for (_, conn) in conns.iter_mut() {
            if let Some(msg) = notify {
                let _ = conn.stream.set_write_timeout(Some(Duration::from_secs(2)));
                if let Ok(b) = wire::write_frame(&mut conn.stream, msg, UNLABELLED, 0) {
                    Shared::add(&self.shared.sent, UNLABELLED, b);
                }
            }
            let _ = conn.stream.shutdown(std::net::Shutdown::Both);
        }
        conns.clear();
        drop(conns);
        // surface this endpoint's control-byte total exactly once: an
        // `overhead_bytes` instant on the *sent* basis, so summing the
        // instants across all endpoints counts each wire byte once —
        // the same invariant `sent_ledger` gives labelled traffic
        if !already_down {
            let overhead = lock_ok(&self.shared.sent)
                .get(&UNLABELLED)
                .copied()
                .unwrap_or(0);
            if overhead > 0 {
                obs::with_current(|t| t.instant(obs::EV_OVERHEAD_BYTES, Some(overhead)));
            }
        }
        self.shared.inbox.close();
        // wake the accept loop so it observes the shutdown flag
        let _ = TcpStream::connect(self.local_addr);
    }
}

impl Transport for TcpTransport {
    fn party(&self) -> PartyId {
        self.party
    }

    fn round_enter(&self, label: u64, _senders: usize) -> Result<()> {
        // no cross-process rendezvous: real sockets impose no global
        // round ordering; the label is recorded for traffic attribution
        let mut open = lock_ok(&self.open_label);
        *open = Some(label);
        Ok(())
    }

    fn session(&self) -> u64 {
        self.shared.session
    }

    fn send(&self, to: PartyId, msg: ClusterMsg) -> Result<u64> {
        if self.shared.shutdown.load(Ordering::SeqCst) {
            return Err(Error::Runtime("tcp transport: endpoint is shut down".into()));
        }
        let label = lock_ok(&self.open_label).unwrap_or(UNLABELLED);
        self.write_to(to, &msg, label)
    }

    fn round_leave(&self, label: u64) -> Result<()> {
        let mut open = lock_ok(&self.open_label);
        if *open != Some(label) {
            return Err(Error::Runtime(format!(
                "tcp transport: leave({label}) without matching enter (open: {:?})",
                *open
            )));
        }
        *open = None;
        Ok(())
    }

    fn recv(&self) -> Result<ClusterMsg> {
        self.shared.inbox.recv().map_err(|e| {
            match lock_ok(&self.shared.abort_reason).as_ref() {
                Some(r) => Error::Runtime(format!("federation aborted: {r}")),
                None => e,
            }
        })
    }

    fn meters(&self) -> (f64, u64) {
        (0.0, self.total_bytes())
    }

    fn abort(&self, reason: &str) {
        self.shared
            .fail(format!("party {} failed: {reason}", self.party));
        // best effort: reach every peer in the address book. The open
        // connection is tried first; if it is dead (possibly the very
        // socket whose loss caused this abort) fall back to one short
        // fresh connect so a peer blocked on us still learns the
        // reason instead of idling out.
        let notify = ClusterMsg::Abort {
            from: self.party,
            reason: reason.to_string(),
        };
        if let Some(peers) = self.peers.get() {
            let mut conns = lock_ok(&self.shared.conns);
            for &pid in peers.keys() {
                if pid == self.party {
                    continue;
                }
                let on_open = conns.get_mut(&pid).map(|c| {
                    let _ = c.stream.set_write_timeout(Some(Duration::from_secs(2)));
                    wire::write_frame(&mut c.stream, &notify, UNLABELLED, 0)
                });
                match on_open {
                    Some(Ok(b)) => Shared::add(&self.shared.sent, UNLABELLED, b),
                    _ => {
                        conns.remove(&pid);
                        if let Ok((mut s, _)) = self.connect_peer(pid, Duration::from_secs(2)) {
                            let _ = s.set_write_timeout(Some(Duration::from_secs(2)));
                            if let Ok(b) = wire::write_frame(&mut s, &notify, UNLABELLED, 0) {
                                Shared::add(&self.shared.sent, UNLABELLED, b);
                            }
                        }
                    }
                }
            }
        }
        self.teardown(None);
    }

    fn close(&self) {
        self.teardown(Some(&ClusterMsg::Shutdown { from: self.party }));
    }
}

impl Drop for TcpTransport {
    fn drop(&mut self) {
        if !self.shared.shutdown.load(Ordering::SeqCst) {
            self.teardown(None);
        }
    }
}

// ---------------------------------------------------------------------------
// heartbeat side
// ---------------------------------------------------------------------------

/// Keep every established outgoing connection warm: a `Heartbeat`
/// control frame every quarter of the idle deadline proves liveness to
/// the peer's reader (so idle expiry only ever fires on a genuinely
/// dead peer), and each tick also drains pending round acks so replay
/// buffers shrink even while the sender computes. A heartbeat that
/// cannot be written marks the connection dead; the next protocol send
/// discovers that and reconnects + replays.
fn heartbeat_loop(shared: Arc<Shared>) {
    loop {
        let t0 = Instant::now();
        loop {
            if shared.shutdown.load(Ordering::SeqCst) {
                return;
            }
            let tick = (shared.idle_timeout() / 4).max(Duration::from_millis(50));
            if t0.elapsed() >= tick {
                break;
            }
            std::thread::sleep(Duration::from_millis(50));
        }
        let frame = wire::encode_frame(
            &ClusterMsg::Heartbeat { from: shared.party },
            UNLABELLED,
            0,
        );
        let idle = shared.idle_timeout();
        let mut conns = lock_ok(&shared.conns);
        let mut dead: Vec<PartyId> = Vec::new();
        for (&to, conn) in conns.iter_mut() {
            shared.drain_acks(to, conn);
            let _ = conn.stream.set_write_timeout(Some(Duration::from_secs(2)));
            let ok = conn.stream.write_all(&frame).is_ok();
            let _ = conn.stream.set_write_timeout(Some(idle));
            if ok {
                shared.add_sent_unless_down(frame.len() as u64);
            } else {
                dead.push(to);
            }
        }
        for to in dead {
            conns.remove(&to);
        }
    }
}

// ---------------------------------------------------------------------------
// acceptor side
// ---------------------------------------------------------------------------

fn accept_loop(listener: TcpListener, shared: Arc<Shared>, handshake_timeout: Duration) {
    loop {
        let conn = listener.accept();
        if shared.shutdown.load(Ordering::SeqCst) {
            return;
        }
        let Ok((stream, _)) = conn else { continue };
        let shared = Arc::clone(&shared);
        let _ = std::thread::Builder::new()
            .name(format!("fedsvd-reader-{}", shared.party))
            .spawn(move || reader(stream, shared, handshake_timeout));
    }
}

/// Validate one inbound handshake; answer with an ack carrying the
/// last sequence we delivered from this sender (0 on a fresh pairing),
/// which is everything a reconnect needs to replay exactly the missing
/// suffix. Returns the connecting party's id and this connection's
/// handshake generation (per party, monotonic) when accepted.
fn handshake_in(
    stream: &mut TcpStream,
    shared: &Shared,
    timeout: Duration,
) -> Result<(PartyId, u64)> {
    stream.set_read_timeout(Some(timeout))?;
    let mut hello = [0u8; HELLO_LEN];
    stream.read_exact(&mut hello)?;
    let magic = u32::from_le_bytes(hello[0..4].try_into().expect("len 4"));
    if magic != HELLO_MAGIC {
        return Err(Error::Protocol("tcp transport: bad hello magic".into()));
    }
    let version = u16::from_le_bytes(hello[4..6].try_into().expect("len 2"));
    let _flags = u16::from_le_bytes(hello[6..8].try_into().expect("len 2"));
    let session = u64::from_le_bytes(hello[8..16].try_into().expect("len 8"));
    let from = u64::from_le_bytes(hello[16..24].try_into().expect("len 8")) as PartyId;
    let to = u64::from_le_bytes(hello[24..32].try_into().expect("len 8")) as PartyId;
    let _sent_seq = u64::from_le_bytes(hello[32..40].try_into().expect("len 8"));
    let status = if version != WIRE_VERSION {
        ACK_BAD_VERSION
    } else if session != shared.session {
        ACK_BAD_SESSION
    } else if to != shared.party {
        ACK_BAD_TARGET
    } else {
        ACK_OK
    };
    let delivered = if status == ACK_OK {
        lock_ok(&shared.delivered).get(&from).copied().unwrap_or(0)
    } else {
        0
    };
    let mut ack = Vec::with_capacity(ACK_LEN);
    ack.extend_from_slice(&HELLO_MAGIC.to_le_bytes());
    ack.extend_from_slice(&WIRE_VERSION.to_le_bytes());
    ack.extend_from_slice(&status.to_le_bytes());
    ack.extend_from_slice(&delivered.to_le_bytes());
    stream.write_all(&ack)?;
    shared.add_sent_unless_down(ACK_LEN as u64);
    if status != ACK_OK {
        return Err(Error::Protocol(format!(
            "tcp transport: rejected inbound handshake (status {status})"
        )));
    }
    Shared::add(&shared.recvd, UNLABELLED, HELLO_LEN as u64);
    // bugfix: never block forever on a half-open socket — a peer silent
    // past the idle deadline (heartbeats cover quiet rounds) is lost
    stream.set_read_timeout(Some(shared.idle_timeout()))?;
    // the reverse direction carries only tiny ack records; bound those
    stream.set_write_timeout(Some(Duration::from_secs(2)))?;
    let gen = {
        let mut h = lock_ok(&shared.handshakes);
        let e = h.entry(from).or_insert(0);
        *e += 1;
        *e
    };
    Ok((from, gen))
}

/// Whether a newer inbound handshake from `from` has taken over.
fn superseded(shared: &Shared, from: PartyId, my_gen: u64) -> bool {
    lock_ok(&shared.handshakes)
        .get(&from)
        .is_some_and(|&g| g > my_gen)
}

/// Push one acknowledgement record for everything delivered from
/// `from` back on the reverse direction of the frame socket. Best
/// effort — returns `false` (disabling further acks on this
/// connection) on a write error; acks only bound the sender's
/// replay-buffer memory, never correctness.
fn send_round_ack(stream: &mut TcpStream, shared: &Shared, from: PartyId) -> bool {
    let seq = lock_ok(&shared.delivered).get(&from).copied().unwrap_or(0);
    if seq == 0 {
        return true;
    }
    let mut rec = Vec::with_capacity(ACK_RECORD_LEN);
    rec.extend_from_slice(&ACK_RECORD_MAGIC.to_le_bytes());
    rec.extend_from_slice(&0u32.to_le_bytes());
    rec.extend_from_slice(&seq.to_le_bytes());
    if stream.write_all(&rec).is_ok() {
        shared.add_sent_unless_down(ACK_RECORD_LEN as u64);
        true
    } else {
        false
    }
}

/// Per-connection reader: decode frames, deduplicate replays, post
/// fresh messages to the inbox, and acknowledge rounds back to the
/// sender.
fn reader(mut stream: TcpStream, shared: Arc<Shared>, handshake_timeout: Duration) {
    let (from, my_gen) = match handshake_in(&mut stream, &shared, handshake_timeout) {
        Ok(p) => p,
        Err(_) => return, // rejected or wedged: never part of the session
    };
    let mut frames = 0u64;
    // the last delivered frame's round label: a change is a round
    // boundary — the moment to push an ack record back to the sender
    let mut ack_label: Option<u64> = None;
    let mut acks_ok = true;
    loop {
        match wire::read_frame(&mut stream) {
            Ok((msg, label, seq, bytes)) => {
                frames += 1;
                match msg {
                    ClusterMsg::Heartbeat { .. } => {
                        // liveness only; resets the idle clock by arriving
                        Shared::add(&shared.recvd, label, bytes);
                        crate::obs::metrics_live::on_recv(bytes);
                    }
                    ClusterMsg::Abort { from, reason } => {
                        Shared::add(&shared.recvd, label, bytes);
                        crate::obs::metrics_live::on_recv(bytes);
                        shared.fail(format!("party {from} aborted: {reason}"));
                        return;
                    }
                    ClusterMsg::Shutdown { .. } => {
                        Shared::add(&shared.recvd, label, bytes);
                        crate::obs::metrics_live::on_recv(bytes);
                        if acks_ok {
                            send_round_ack(&mut stream, &shared, from);
                        }
                        return; // clean end
                    }
                    msg => {
                        // dedup + post under one `delivered` lock so a
                        // racing superseded connection cannot reorder
                        let fresh = {
                            let mut d = lock_ok(&shared.delivered);
                            let e = d.entry(from).or_insert(0);
                            if seq != 0 && seq <= *e {
                                false
                            } else {
                                if seq != 0 {
                                    *e = seq;
                                }
                                Shared::add(&shared.recvd, label, bytes);
                                crate::obs::metrics_live::on_recv(bytes);
                                if shared.inbox.post(msg).is_err() {
                                    return; // we are shutting down ourselves
                                }
                                true
                            }
                        };
                        if !fresh {
                            // a replayed duplicate: metered separately,
                            // never ledgered, never delivered twice
                            shared
                                .replay_recvd_bytes
                                .fetch_add(bytes, Ordering::Relaxed);
                            continue;
                        }
                        if acks_ok && ack_label.is_some_and(|l| l != label) {
                            acks_ok = send_round_ack(&mut stream, &shared, from);
                        }
                        ack_label = Some(label);
                    }
                }
            }
            Err(e) => {
                if shared.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                let timed_out = matches!(
                    &e,
                    Error::Io(io) if io.kind() == std::io::ErrorKind::WouldBlock
                        || io.kind() == std::io::ErrorKind::TimedOut
                );
                if timed_out {
                    // idle deadline expired: not one frame — not even a
                    // heartbeat — for the whole window. Half-open socket.
                    if !superseded(&shared, from, my_gen) {
                        shared.fail(format!(
                            "connection to party {from} idle past the deadline \
                             ({}s without frames or heartbeats): peer presumed lost",
                            shared.idle_timeout().as_secs()
                        ));
                    }
                    return;
                }
                // EOF/reset without a Shutdown frame: recoverable socket
                // death. Give the peer's reconnect a bounded grace window
                // to supersede this connection before declaring it lost.
                // A zero-frame stream is usually an abandoned handshake
                // retry (see connect_peer) and gets the short window; a
                // stream that carried real frames gets the reconnect
                // grace (the peer is actively retrying with backoff).
                let grace = if frames == 0 {
                    Duration::from_secs(2)
                } else {
                    shared.reconnect_grace
                };
                let deadline = Instant::now() + grace;
                loop {
                    if shared.shutdown.load(Ordering::SeqCst) {
                        return;
                    }
                    if lock_ok(&shared.abort_reason).is_some() {
                        return; // federation already failed: first reason wins
                    }
                    if superseded(&shared, from, my_gen) {
                        return; // the reconnect's connection took over
                    }
                    if Instant::now() >= deadline {
                        break;
                    }
                    std::thread::sleep(Duration::from_millis(25));
                }
                shared.fail(format!("connection to party {from} lost"));
                return;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::link::{CSP, USER_BASE};

    /// Loopback sockets may be forbidden in exotic sandboxes; skip
    /// rather than fail there (CI runs these for real).
    fn loopback_available() -> bool {
        std::net::TcpListener::bind("127.0.0.1:0").is_ok()
    }

    fn pair(session: u64) -> (TcpTransport, TcpTransport) {
        let a = TcpTransport::bind("127.0.0.1:0", CSP, session).unwrap();
        let b = TcpTransport::bind("127.0.0.1:0", USER_BASE, session).unwrap();
        let addrs: HashMap<PartyId, String> = [
            (CSP, a.local_addr().to_string()),
            (USER_BASE, b.local_addr().to_string()),
        ]
        .into_iter()
        .collect();
        a.set_peers(addrs.clone()).unwrap();
        b.set_peers(addrs).unwrap();
        (a, b)
    }

    #[test]
    fn frames_flow_and_real_bytes_are_ledgered() {
        if !loopback_available() {
            eprintln!("skipping: loopback TCP unavailable");
            return;
        }
        let (csp, user) = pair(11);
        user.round_enter(5, 1).unwrap();
        user.send(CSP, ClusterMsg::Sigma(vec![2.0, -0.0])).unwrap();
        user.round_leave(5).unwrap();
        let ClusterMsg::Sigma(s) = csp.recv().unwrap() else {
            panic!("wrong message")
        };
        assert_eq!(s[0], 2.0);
        assert_eq!(s[1].to_bits(), (-0.0f64).to_bits());
        // 32 B frame header + 8 B count + 16 B payload, plus the 40 B hello
        let sent = user.sent_ledger();
        assert!(sent.contains(&(5, 56)), "sent ledger: {sent:?}");
        assert!(sent.contains(&(UNLABELLED, 40)), "sent ledger: {sent:?}");
        user.close();
        csp.close();
    }

    #[test]
    fn session_mismatch_is_rejected() {
        if !loopback_available() {
            eprintln!("skipping: loopback TCP unavailable");
            return;
        }
        let a = TcpTransport::bind("127.0.0.1:0", CSP, 1).unwrap();
        let b = TcpTransport::bind("127.0.0.1:0", USER_BASE, 2).unwrap();
        let addrs: HashMap<PartyId, String> = [
            (CSP, a.local_addr().to_string()),
            (USER_BASE, b.local_addr().to_string()),
        ]
        .into_iter()
        .collect();
        a.set_peers(addrs.clone()).unwrap();
        b.set_peers(addrs).unwrap();
        let err = b.send(CSP, ClusterMsg::Shutdown { from: USER_BASE });
        assert!(err.is_err());
        a.close();
        b.close();
    }

    #[test]
    fn connect_retries_with_backoff_until_the_peer_binds() {
        if !loopback_available() {
            eprintln!("skipping: loopback TCP unavailable");
            return;
        }
        // reserve an ephemeral port, free it, and bring the peer up late:
        // the first connects are refused, the retry/backoff path must
        // carry the send through once the listener finally binds
        let probe = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = probe.local_addr().unwrap().to_string();
        drop(probe);
        let user = TcpTransport::bind("127.0.0.1:0", USER_BASE, 77).unwrap();
        let addrs: HashMap<PartyId, String> = [
            (CSP, addr.clone()),
            (USER_BASE, user.local_addr().to_string()),
        ]
        .into_iter()
        .collect();
        user.set_peers(addrs).unwrap();
        let late = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(250));
            let csp = TcpTransport::bind(&addr, CSP, 77).unwrap();
            let msg = csp.recv().unwrap();
            assert!(matches!(msg, ClusterMsg::Sigma(_)));
            csp.close();
        });
        user.round_enter(1, 1).unwrap();
        user.send(CSP, ClusterMsg::Sigma(vec![1.0])).unwrap();
        user.round_leave(1).unwrap();
        late.join().unwrap();
        user.close();
    }

    #[test]
    fn abort_frame_fails_the_peer_with_the_reason() {
        if !loopback_available() {
            eprintln!("skipping: loopback TCP unavailable");
            return;
        }
        let (csp, user) = pair(12);
        user.abort("injected failure");
        let err = csp.recv().unwrap_err();
        let text = err.to_string();
        assert!(text.contains("injected failure"), "got: {text}");
        csp.close();
    }

    /// The tentpole path end to end: an established connection is
    /// severed under the transport mid-protocol; the next send must
    /// reconnect, resume-handshake, replay the unacked suffix, and the
    /// receiver must deliver every message exactly once, in order.
    #[test]
    fn severed_socket_reconnects_and_replays_without_duplicates() {
        if !loopback_available() {
            eprintln!("skipping: loopback TCP unavailable");
            return;
        }
        let (csp, user) = pair(21);
        user.round_enter(5, 1).unwrap();
        user.send(CSP, ClusterMsg::Sigma(vec![1.0])).unwrap();
        let ClusterMsg::Sigma(s) = csp.recv().unwrap() else {
            panic!("wrong message")
        };
        assert_eq!(s, vec![1.0]);
        // the network silently dies under the established connection
        assert!(user.sever_conn(CSP), "no established connection to sever");
        user.send(CSP, ClusterMsg::Sigma(vec![2.0])).unwrap();
        user.send(CSP, ClusterMsg::Sigma(vec![3.0])).unwrap();
        user.round_leave(5).unwrap();
        let ClusterMsg::Sigma(s) = csp.recv().unwrap() else {
            panic!("wrong message")
        };
        assert_eq!(s, vec![2.0], "first post-sever message");
        let ClusterMsg::Sigma(s) = csp.recv().unwrap() else {
            panic!("wrong message")
        };
        assert_eq!(s, vec![3.0], "second post-sever message");
        assert_eq!(user.reconnects(), 1, "exactly one reconnect");
        // the first message was already delivered, so the resume
        // handshake (delivered = 1) retired it instead of replaying it:
        // nothing re-crossed the wire, nothing was double-ledgered
        assert_eq!(user.replayed_bytes(), 0, "delivered frame must be retired, not replayed");
        assert_eq!(csp.replayed_recv_bytes(), 0, "no duplicate reached the receiver");
        // the round ledger counted each frame exactly once despite the
        // replay: 3 sigma frames of 48 B each under label 5
        let sent = user.sent_ledger();
        assert!(sent.contains(&(5, 144)), "sent ledger: {sent:?}");
        user.close();
        csp.close();
    }

    /// With retries exhausted (0 attempts) a dead socket is definitive
    /// peer loss: the send errors instead of hanging or panicking.
    #[test]
    fn reconnect_retries_exhausted_is_clean_peer_loss() {
        if !loopback_available() {
            eprintln!("skipping: loopback TCP unavailable");
            return;
        }
        let (csp, user) = pair(22);
        user.set_reconnect_retries(0);
        user.round_enter(5, 1).unwrap();
        user.send(CSP, ClusterMsg::Sigma(vec![1.0])).unwrap();
        assert!(user.sever_conn(CSP));
        let err = user.send(CSP, ClusterMsg::Sigma(vec![2.0])).unwrap_err();
        let text = err.to_string();
        assert!(
            text.contains("lost connection to party 1") && text.contains("reconnect failed"),
            "got: {text}"
        );
        user.close();
        csp.close();
    }

    /// A half-open connection (peer vanishes without FIN, heartbeats
    /// stop) must surface as peer loss via the idle deadline instead of
    /// blocking `recv` forever.
    #[test]
    fn idle_timeout_surfaces_half_open_connection_as_peer_loss() {
        if !loopback_available() {
            eprintln!("skipping: loopback TCP unavailable");
            return;
        }
        let csp = TcpTransport::bind("127.0.0.1:0", CSP, 33).unwrap();
        csp.set_idle_timeout(Duration::from_millis(300));
        // a raw client that completes a valid handshake, then goes
        // silent forever — no frames, no heartbeats, no FIN
        let mut s = TcpStream::connect(csp.local_addr()).unwrap();
        let mut hello = Vec::with_capacity(HELLO_LEN);
        hello.extend_from_slice(&HELLO_MAGIC.to_le_bytes());
        hello.extend_from_slice(&WIRE_VERSION.to_le_bytes());
        hello.extend_from_slice(&0u16.to_le_bytes());
        hello.extend_from_slice(&33u64.to_le_bytes());
        hello.extend_from_slice(&(USER_BASE as u64).to_le_bytes());
        hello.extend_from_slice(&(CSP as u64).to_le_bytes());
        hello.extend_from_slice(&0u64.to_le_bytes());
        s.write_all(&hello).unwrap();
        let mut ack = [0u8; ACK_LEN];
        s.read_exact(&mut ack).unwrap();
        let err = csp.recv().unwrap_err();
        let text = err.to_string();
        assert!(text.contains("idle past the deadline"), "got: {text}");
        drop(s);
        csp.close();
    }

    /// A panic while holding a shared lock must not cascade: the
    /// poison-recovering locks keep the transport usable so the failure
    /// stays scoped to the panicking thread.
    #[test]
    fn poisoned_locks_recover_instead_of_cascading() {
        if !loopback_available() {
            eprintln!("skipping: loopback TCP unavailable");
            return;
        }
        let (csp, user) = pair(44);
        let shared = Arc::clone(&user.shared);
        let _ = std::thread::spawn(move || {
            let _guard = shared.sent.lock().unwrap();
            panic!("poison the sent ledger on purpose");
        })
        .join();
        assert!(user.shared.sent.is_poisoned(), "test setup: lock not poisoned");
        user.round_enter(5, 1).unwrap();
        user.send(CSP, ClusterMsg::Sigma(vec![4.0])).unwrap();
        user.round_leave(5).unwrap();
        let ClusterMsg::Sigma(s) = csp.recv().unwrap() else {
            panic!("wrong message")
        };
        assert_eq!(s, vec![4.0]);
        assert!(
            user.sent_ledger().iter().any(|&(l, _)| l == 5),
            "ledger still readable after poisoning"
        );
        user.close();
        csp.close();
    }
}
