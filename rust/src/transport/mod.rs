//! The wire seam between the cluster protocol and its deployment.
//!
//! PR 2/3 ran TA/CSP/users as threads in one process over in-memory
//! mailboxes; the deployment the paper actually evaluates is separate
//! hosts exchanging bytes. This subsystem makes that a seam instead of a
//! rewrite:
//!
//! * [`wire`] — the versioned, length-prefixed little-endian binary
//!   codec ([`ClusterMsg`], `encode_frame`/`read_frame`): every cluster
//!   message as bytes, with f64 payloads round-tripping bit-exactly.
//! * [`Transport`] — what a party needs from its network: metered round
//!   membership (`round_enter`/`round_leave`), `send(peer, msg)`,
//!   blocking `recv`, and failure propagation (`abort`/`close`).
//! * [`local::LocalTransport`] — the in-process implementation: posts
//!   through [`crate::cluster::mailbox`] and meters **simulated** bytes
//!   ([`ClusterMsg::sim_wire_bytes`]) through the shared
//!   [`crate::cluster::round::RoundScheduler`]/[`crate::net::NetSim`]
//!   model, preserving the PR 2/3 metering bit-for-bit.
//! * [`tcp::TcpTransport`] — real sockets on `std::net` (zero new
//!   dependencies): per-peer framed streams, a handshake carrying
//!   session id + party id + protocol version, and a traffic ledger of
//!   **real** on-the-wire bytes per round label. Since wire v3 the
//!   transport *survives mid-protocol socket loss*: frames are
//!   sequenced per peer and retained in replay buffers until the
//!   receiver's round acknowledgement retires them; every handshake is
//!   a potential resume (the ack reports the receiver's last-delivered
//!   sequence), so a reconnect replays exactly the unacked suffix and
//!   the receiver's dedup drops anything it already delivered — party
//!   bodies never observe the drop. Half-open sockets surface as peer
//!   loss via an idle deadline kept honest by heartbeat frames.
//!
//! The party loops in [`crate::cluster::runtime`] are written against
//! the trait only, so the same choreography runs as threads
//! (`ExecMode::Cluster`), as loopback-TCP threads (benches/tests), or
//! as N real OS processes (`ExecMode::Distributed`, `fedsvd serve`).
//!
//! Round semantics across implementations: the round *label* is part of
//! the contract (it keys the traffic ledger on both), but only the
//! simulated transport serializes rounds globally — real sockets order
//! bytes per connection, not per federation, so receivers must tolerate
//! cross-peer interleaving (the runtime's `PartyLink` hold-back queue
//! does exactly that).

pub mod local;
pub mod tcp;
pub mod wire;

use crate::net::link::PartyId;
use crate::util::Result;

pub use local::LocalTransport;
pub use tcp::TcpTransport;
pub use wire::ClusterMsg;

/// One party's endpoint into the federation's network.
///
/// Exactly one party thread/process drives an endpoint: `recv` competes
/// with nobody, and `round_enter`/`round_leave` bracket that party's
/// sends of one labelled round (see [`crate::cluster::runtime::labels`]).
pub trait Transport: Send {
    /// This endpoint's party id ([`crate::net::link`] numbering).
    fn party(&self) -> PartyId;

    /// The federation session id this endpoint belongs to (stamped on
    /// trace events; the TCP handshake already carries it). Simulated
    /// fabrics thread the config seed through.
    fn session(&self) -> u64 {
        0
    }

    /// Join round `label` as one of `senders` concurrent sending
    /// parties. Simulated transports rendezvous here (concurrent
    /// uploads share one metered round); real transports only record
    /// the label for traffic attribution.
    fn round_enter(&self, label: u64, senders: usize) -> Result<()>;

    /// Send one message to `to`, metered under the open round's label.
    /// Returns the bytes this transport *metered* for the message — the
    /// same figure its traffic ledger records (simulated wire bytes on
    /// [`local::LocalTransport`], real frame bytes on
    /// [`tcp::TcpTransport`]) — so callers can attribute traffic (trace
    /// `send` events) without re-deriving transport-specific sizes.
    fn send(&self, to: PartyId, msg: ClusterMsg) -> Result<u64>;

    /// Declare this party done sending in round `label`.
    fn round_leave(&self, label: u64) -> Result<()>;

    /// Block until the next message addressed to this party arrives.
    /// Errors once the federation is aborted or torn down.
    fn recv(&self) -> Result<ClusterMsg>;

    /// Live meters as (simulated network seconds, total bytes seen by
    /// this endpoint). Simulated transports report the shared `NetSim`
    /// clock; real transports report 0 simulated seconds and real
    /// socket bytes.
    fn meters(&self) -> (f64, u64);

    /// Propagate a local failure: tell every peer (so their `recv`s
    /// error instead of hanging) and unblock anything waiting locally.
    fn abort(&self, reason: &str);

    /// Clean teardown after this party finished its protocol role.
    fn close(&self);
}
