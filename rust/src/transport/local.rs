//! In-process transport: mailboxes + the simulated network model.
//!
//! [`LocalTransport`] is the PR 2/3 wiring behind the [`Transport`]
//! trait: delivery is a [`Mailbox`] post, metering is the shared
//! [`RoundScheduler`] over [`crate::net::NetSim`], and the byte charged
//! per message is [`ClusterMsg::sim_wire_bytes`] — exactly what the
//! pre-transport runtime metered, so every simulated-time number and
//! per-label traffic pin is unchanged by the transport seam.

use std::sync::Arc;

use crate::cluster::mailbox::Mailbox;
use crate::cluster::round::RoundScheduler;
use crate::net::link::PartyId;
use crate::net::LinkSpec;
use crate::util::{Error, Result};

use super::wire::ClusterMsg;
use super::Transport;

/// One party's endpoint of the in-process fabric.
pub struct LocalTransport {
    party: PartyId,
    session: u64,
    sched: Arc<RoundScheduler>,
    /// Every party's inbox, indexed by `PartyId` (TA 0, CSP 1, users 2+).
    boxes: Arc<Vec<Mailbox<ClusterMsg>>>,
}

impl LocalTransport {
    /// Build the full in-process fabric for `k` users: one endpoint per
    /// party in `PartyId` order (TA, CSP, user 0..k), all sharing one
    /// round scheduler whose meters/ledger survive the endpoints.
    /// `session` stamps this federation's trace events.
    pub fn fabric(
        k: usize,
        link: LinkSpec,
        session: u64,
    ) -> (Vec<LocalTransport>, Arc<RoundScheduler>) {
        let sched = Arc::new(RoundScheduler::new(link));
        let boxes: Arc<Vec<Mailbox<ClusterMsg>>> =
            Arc::new((0..k + 2).map(|_| Mailbox::new()).collect());
        let endpoints = (0..k + 2)
            .map(|party| LocalTransport {
                party,
                session,
                sched: Arc::clone(&sched),
                boxes: Arc::clone(&boxes),
            })
            .collect();
        (endpoints, sched)
    }
}

impl Transport for LocalTransport {
    fn party(&self) -> PartyId {
        self.party
    }

    fn session(&self) -> u64 {
        self.session
    }

    fn round_enter(&self, label: u64, senders: usize) -> Result<()> {
        self.sched.enter(label, senders)
    }

    fn send(&self, to: PartyId, msg: ClusterMsg) -> Result<u64> {
        let inbox = self
            .boxes
            .get(to)
            .ok_or_else(|| Error::Runtime(format!("local transport: no party {to}")))?;
        let bytes = msg.sim_wire_bytes();
        self.sched.send(self.party, to, bytes);
        // a closed peer inbox means that party aborted — surface it now
        // instead of letting a later round hang on the missing reply
        inbox
            .post(msg)
            .map_err(|_| Error::Runtime(format!("peer party {to} aborted (inbox closed)")))?;
        Ok(bytes)
    }

    fn round_leave(&self, label: u64) -> Result<()> {
        self.sched.leave(label)
    }

    fn recv(&self) -> Result<ClusterMsg> {
        self.boxes[self.party].recv()
    }

    fn meters(&self) -> (f64, u64) {
        self.sched.with_net(|n| (n.sim_elapsed_s(), n.total_bytes()))
    }

    fn abort(&self, _reason: &str) {
        self.sched.abort();
        for b in self.boxes.iter() {
            b.close();
        }
    }

    fn close(&self) {
        // only this party's inbox: peers may still be mid-protocol and
        // their queues must keep working
        self.boxes[self.party].close();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::link::{CSP, USER_BASE};

    #[test]
    fn send_meters_sim_bytes_and_delivers() {
        let (eps, sched) = LocalTransport::fabric(2, LinkSpec::default(), 0);
        let user0 = &eps[USER_BASE];
        let csp = &eps[CSP];
        user0.round_enter(7, 1).unwrap();
        user0
            .send(CSP, ClusterMsg::Sigma(vec![1.0, 2.0, 3.0]))
            .unwrap();
        user0.round_leave(7).unwrap();
        let ClusterMsg::Sigma(s) = csp.recv().unwrap() else {
            panic!("wrong message")
        };
        assert_eq!(s, vec![1.0, 2.0, 3.0]);
        assert_eq!(sched.labelled_bytes(), vec![(7, 24)]);
    }

    #[test]
    fn abort_closes_every_inbox_and_post_errors() {
        let (eps, _sched) = LocalTransport::fabric(2, LinkSpec::default(), 0);
        eps[USER_BASE].abort("test failure");
        assert!(eps[CSP].recv().is_err());
        assert!(eps[CSP]
            .send(USER_BASE + 1, ClusterMsg::Shutdown { from: CSP })
            .is_err());
    }
}
