//! The FedSVD federated protocol (paper §3, Fig. 3).
//!
//! Roles: **TA** (generates removable masks, then goes offline), **CSP**
//! (runs standard SVD on the masked aggregate), **users** (own the data,
//! apply and remove masks). All roles execute in-process; every message is
//! metered through [`crate::net::NetSim`] with the paper's round model.
//!
//! * [`fedsvd`] — 4-step orchestration.
//! * [`v_recovery`] — the federated recovery of `Vᵢᵀ` (Eq. 6–7): user
//!   masks `Qᵢᵀ` with a block-diagonal random `Rᵢ`, the CSP returns
//!   `V'ᵀ·QᵢᵀRᵢ`, the user strips `Rᵢ⁻¹`.
//! * [`privacy`] — Theorem 2 machinery (unidentifiability witnesses) and
//!   moment checks used by the attack evaluation.

pub mod fedsvd;
pub mod horizontal;
pub mod v_recovery;
pub mod privacy;

pub use horizontal::{
    run_fedsvd_horizontal, run_fedsvd_horizontal_with_backend, HorizontalOutput,
};
pub use fedsvd::{
    run_fedsvd, run_fedsvd_with_backend, FedSvdConfig, FedSvdOutput, OptFlags, SvdMode,
};

use crate::linalg::Mat;
use crate::util::{Error, Result};

/// Split a joint matrix vertically into `k` near-equal user parts
/// (the paper's default: "uniformly partition the data on two users").
pub fn split_columns(x: &Mat, k: usize) -> Result<Vec<Mat>> {
    if k == 0 || k > x.cols() {
        return Err(Error::Shape(format!(
            "split_columns: k={k} for {} cols",
            x.cols()
        )));
    }
    let n = x.cols();
    let base = n / k;
    let extra = n % k;
    let mut out = Vec::with_capacity(k);
    let mut c0 = 0usize;
    for i in 0..k {
        let w = base + usize::from(i < extra);
        out.push(x.slice(0, x.rows(), c0, c0 + w));
        c0 += w;
    }
    Ok(out)
}

/// Column boundaries of the same split (prefix offsets, length k+1).
pub fn split_bounds(n: usize, k: usize) -> Vec<usize> {
    let base = n / k;
    let extra = n % k;
    let mut b = Vec::with_capacity(k + 1);
    let mut acc = 0usize;
    b.push(0);
    for i in 0..k {
        acc += base + usize::from(i < extra);
        b.push(acc);
    }
    b
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;

    #[test]
    fn split_columns_covers_all() {
        let mut rng = Xoshiro256::seed_from_u64(1);
        let x = Mat::gaussian(4, 10, &mut rng);
        let parts = split_columns(&x, 3).unwrap();
        assert_eq!(parts.len(), 3);
        let widths: Vec<usize> = parts.iter().map(|p| p.cols()).collect();
        assert_eq!(widths, vec![4, 3, 3]);
        let rebuilt = parts[0].hcat(&parts[1]).unwrap().hcat(&parts[2]).unwrap();
        assert_eq!(rebuilt.data(), x.data());
    }

    #[test]
    fn split_bounds_match_split_columns() {
        let b = split_bounds(10, 3);
        assert_eq!(b, vec![0, 4, 7, 10]);
        let b2 = split_bounds(9, 3);
        assert_eq!(b2, vec![0, 3, 6, 9]);
    }

    #[test]
    fn split_rejects_bad_k() {
        let x = Mat::zeros(2, 3);
        assert!(split_columns(&x, 0).is_err());
        assert!(split_columns(&x, 4).is_err());
    }
}
