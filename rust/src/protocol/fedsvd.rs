//! The 4-step FedSVD orchestration (paper §3, Fig. 3).

use super::v_recovery;
use crate::linalg::{
    randomized_svd, run_parallel_collect, svd, CpuBackend, GemmBackend, Mat, MatView, SvdResult,
};
use crate::mask::block_diag::{BlockDiagMat, BlockDiagSlice};
use crate::mask::delivery::{dense_delivery_bytes, SeedDelivery, SliceDelivery};
use crate::mask::orthogonal::random_orthogonal;
use crate::metrics::MetricsRecorder;
use crate::net::link::{CSP, TA, USER_BASE};
use crate::net::{LinkSpec, NetSim};
use crate::rng::Xoshiro256;
use crate::secagg::{minibatch, SecAggGroup};
use crate::util::{Error, Result};

/// Which decomposition the CSP runs in Step 3.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SvdMode {
    /// Full lossless SVD (Jacobi) — the SVD-task experiments.
    Full,
    /// Randomized truncated SVD with `rank` components — PCA / LSA mode.
    Truncated { rank: usize },
}

/// `(oversample, power_iters)` for the randomized truncated CSP SVD.
/// Shared by the sequential oracle and the cluster runtime so the two
/// execution paths cannot drift apart: generous oversampling + power
/// iterations because the paper's apps feed decaying spectra, but flat
/// spectra must not break tests.
pub fn truncated_svd_tuning(rank: usize) -> (usize, usize) {
    (rank.max(10), 6)
}

/// The Step-3 randomized-probe seed stream, derived from the protocol
/// seed. One shared derivation for the sequential oracle and the cluster
/// CSP ([`crate::cluster`]) so both execution paths draw *identical*
/// probes — together with the partition-invariant GEMM accumulation this
/// is what lets the app-level equivalence suite hold the truncated
/// applications (PCA / LSA) to ≤ 1e-9 across exec modes.
pub fn step3_probe_seed(protocol_seed: u64) -> u64 {
    Xoshiro256::seed_from_u64(protocol_seed).derive(0xc5b).next_u64()
}

/// The paper's three optimization families (Fig. 7 ablation switches).
#[derive(Debug, Clone, Copy)]
pub struct OptFlags {
    /// Opt1: block-based mask generation / masking / recovery.
    /// Off ⇒ dense Algorithm-1 masks, dense delivery, dense products.
    pub block_masks: bool,
    /// Opt2: mini-batch secure aggregation (server memory bound).
    pub minibatch_secagg: bool,
}

impl Default for OptFlags {
    fn default() -> Self {
        Self {
            block_masks: true,
            minibatch_secagg: true,
        }
    }
}

/// Full protocol configuration.
#[derive(Debug, Clone)]
pub struct FedSvdConfig {
    /// Mask block size b (paper default 1000; scaled in tests).
    pub block_size: usize,
    /// Rows per secagg mini-batch (Opt2); ignored when minibatch off.
    pub secagg_batch_rows: usize,
    /// Simulated link (paper default 1 Gb/s, RTT 50 ms).
    pub link: LinkSpec,
    pub mode: SvdMode,
    /// Root seed for every randomized piece of the protocol.
    pub seed: u64,
    pub opts: OptFlags,
    /// Recover U at the users (PCA: yes; LR: no — stays at CSP).
    pub recover_u: bool,
    /// Run the federated Vᵢᵀ recovery (LSA/SVD: yes; PCA: no).
    pub recover_v: bool,
}

impl Default for FedSvdConfig {
    fn default() -> Self {
        Self {
            block_size: 64,
            secagg_batch_rows: 64,
            link: LinkSpec::default(),
            mode: SvdMode::Full,
            seed: 0xfed5_7d,
            opts: OptFlags::default(),
            recover_u: true,
            recover_v: true,
        }
    }
}

/// Everything the protocol produces, including the evaluation meters.
pub struct FedSvdOutput {
    /// Shared result U (m×k); `None` when `recover_u` is off.
    pub u: Option<Mat>,
    /// Shared singular values (descending).
    pub s: Vec<f64>,
    /// Per-user secret result Vᵢᵀ (k×nᵢ); empty when `recover_v` is off.
    pub v_parts: Vec<Mat>,
    /// The masked factorization kept at the CSP (U', Σ, V'ᵀ) — exposed for
    /// the applications (LR never ships it to users).
    pub csp_svd: SvdResult,
    /// Masks as seen by the users (needed by the applications' last steps).
    pub p_mask: MaskRep,
    pub q_slices: Vec<QSliceRep>,
    pub metrics: MetricsRecorder,
    pub net: NetSim,
}

/// The left mask in whichever representation the run used.
pub enum MaskRep {
    Block(BlockDiagMat),
    Dense(Mat),
}

impl MaskRep {
    /// `Pᵀ·X` for result unmasking.
    pub fn transpose_mul(&self, x: &Mat) -> Result<Mat> {
        self.transpose_mul_with(x, CpuBackend::global())
    }

    /// `Pᵀ·X` on an explicit backend (transpose flag; no transposed-block
    /// materialization on the block path).
    pub fn transpose_mul_with(&self, x: &Mat, backend: &dyn GemmBackend) -> Result<Mat> {
        match self {
            MaskRep::Block(b) => b.t_mul_dense_with(x, backend),
            MaskRep::Dense(d) => {
                let mut out = Mat::zeros(d.cols(), x.cols());
                backend.gemm_into(1.0, d, true, x, false, 0.0, &mut out)?;
                Ok(out)
            }
        }
    }

    /// `P·y` for LR label masking.
    pub fn mul_vec(&self, y: &[f64]) -> Result<Vec<f64>> {
        match self {
            MaskRep::Block(b) => crate::mask::apply::mask_vector(b, y),
            MaskRep::Dense(d) => d.mul_vec(y),
        }
    }
}

/// A user's share of the right mask.
pub enum QSliceRep {
    Block(BlockDiagSlice),
    /// Dense Qᵢ (nᵢ×n) — the Opt1-off path.
    Dense(Mat),
}

impl QSliceRep {
    /// `w_i = Qᵢ·w'` — the LR parameter unmasking (paper §4).
    pub fn mul_vec(&self, w: &[f64]) -> Result<Vec<f64>> {
        self.mul_vec_with(w, CpuBackend::global())
    }

    /// `w_i = Qᵢ·w'` routed through the backend's scatter GEMM: each piece
    /// multiplies the matching window of `w'` and accumulates into its
    /// local rows — no dense temporaries, no scalar scatter loop.
    pub fn mul_vec_with(&self, w: &[f64], backend: &dyn GemmBackend) -> Result<Vec<f64>> {
        match self {
            QSliceRep::Block(s) => block_q_mul_vec(s, w, backend),
            QSliceRep::Dense(q) => q.mul_vec(w),
        }
    }
}

/// `Σ⁺·x`: scale each entry by the inverse singular value, with the
/// relative pseudo-inverse cutoff (σ ≤ σ₁·1e-12 treated as a null
/// direction). One shared rule for every LR path — the sequential app,
/// the cluster CSP and the centralized reference — so the cutoff cannot
/// drift between them and break the ≤ 1e-9 cross-mode equivalence.
pub fn pinv_scale(s: &[f64], x: &[f64]) -> Vec<f64> {
    let smax = s.first().cloned().unwrap_or(0.0);
    let cutoff = smax * 1e-12;
    x.iter()
        .zip(s)
        .map(|(v, sv)| if *sv > cutoff { v / sv } else { 0.0 })
        .collect()
}

/// `Qᵢ·w'` on a borrowed block slice — the LR coefficient unmasking,
/// shared by [`QSliceRep::mul_vec_with`] and the cluster user threads
/// (which hold their `Qᵢ` slice directly, not wrapped in a `QSliceRep`).
pub fn block_q_mul_vec(
    s: &BlockDiagSlice,
    w: &[f64],
    backend: &dyn GemmBackend,
) -> Result<Vec<f64>> {
    if w.len() != s.cols() {
        return Err(Error::Shape(format!(
            "mul_vec: w' has {} entries, Qᵢ is {}x{}",
            w.len(),
            s.rows(),
            s.cols()
        )));
    }
    let mut out = Mat::zeros(s.rows(), 1);
    for p in s.pieces() {
        let wv = MatView::col(&w[p.global_col..p.global_col + p.mat.cols()]);
        backend.gemm_view_acc(1.0, p.mat.as_view(), wv, &mut out, p.local_row, 0)?;
    }
    Ok(out.into_vec())
}

/// Run FedSVD over vertically-partitioned user parts `[X₁ … X_k]`
/// (each m×nᵢ) on the global CPU backend (`FEDSVD_THREADS` lanes); see
/// [`run_fedsvd_with_backend`].
pub fn run_fedsvd(parts: &[Mat], cfg: &FedSvdConfig) -> Result<FedSvdOutput> {
    run_fedsvd_with_backend(parts, cfg, CpuBackend::global())
}

/// Run FedSVD with an explicit GEMM backend (CPU pool or PJRT tiles).
///
/// Outputs are bit-identical for any backend thread count: every parallel
/// region is partitioned (per-user shares, per-block panels, GEMM row
/// chunks) with a thread-count-independent per-element op order.
pub fn run_fedsvd_with_backend(
    parts: &[Mat],
    cfg: &FedSvdConfig,
    backend: &dyn GemmBackend,
) -> Result<FedSvdOutput> {
    let k_users = parts.len();
    if k_users == 0 {
        return Err(Error::Protocol("no users".into()));
    }
    let m = parts[0].rows();
    for p in parts {
        if p.rows() != m {
            return Err(Error::Shape("users disagree on m".into()));
        }
    }
    let widths: Vec<usize> = parts.iter().map(|p| p.cols()).collect();
    let n: usize = widths.iter().sum();
    if m == 0 || n == 0 {
        return Err(Error::Shape("empty federated matrix".into()));
    }
    let b = cfg.block_size.max(1);

    let mut net = NetSim::new(cfg.link);
    let mut metrics = MetricsRecorder::new();
    let mut rng = Xoshiro256::seed_from_u64(cfg.seed);
    let user_ids: Vec<usize> = (0..k_users).map(|i| USER_BASE + i).collect();

    // ---- Step 1 (paper Step ❶): TA generates and delivers masks --------
    metrics.begin("step1: mask init+delivery", net.sim_elapsed_s(), net.total_bytes());
    let (p_mask, q_slices) = if cfg.opts.block_masks {
        let p_seed = rng.next_u64();
        let q_seed = rng.next_u64();
        let p_delivery = SeedDelivery {
            seed: p_seed,
            dim: m,
            block: b,
        };
        // TA broadcasts the P seed (O(1) per user)
        net.begin_round();
        for &uid in &user_ids {
            net.send(TA, uid, p_delivery.wire_bytes());
        }
        net.end_round();
        // TA builds Q once and ships each user its row slice (O(nᵢ))
        let q = crate::mask::orthogonal::block_orthogonal(n, b, q_seed)?;
        let mut slices = Vec::with_capacity(k_users);
        net.begin_round();
        let mut c0 = 0usize;
        for (i, &w) in widths.iter().enumerate() {
            let s = q.row_slice(c0, c0 + w)?;
            let d = SliceDelivery { slice: s };
            net.send(TA, user_ids[i], d.wire_bytes());
            slices.push(d.slice);
            c0 += w;
        }
        net.end_round();
        // users expand P locally from the seed
        let p = p_delivery.expand()?;
        (
            MaskRep::Block(p),
            slices.into_iter().map(QSliceRep::Block).collect::<Vec<_>>(),
        )
    } else {
        // Opt1 OFF: dense Algorithm-1 masks, O(m²+n²) delivery
        let p = random_orthogonal(m, &mut rng)?;
        let q = random_orthogonal(n, &mut rng)?;
        net.begin_round();
        for &uid in &user_ids {
            net.send(TA, uid, dense_delivery_bytes(m));
        }
        net.end_round();
        net.begin_round();
        let mut c0 = 0usize;
        let mut slices = Vec::with_capacity(k_users);
        for (i, &w) in widths.iter().enumerate() {
            // Qᵢ = rows c0..c0+w of Q
            let qi = q.slice(c0, c0 + w, 0, n);
            net.send(TA, user_ids[i], (w * n * 8) as u64);
            slices.push(QSliceRep::Dense(qi));
            c0 += w;
        }
        net.end_round();
        (MaskRep::Dense(p), slices)
    };
    metrics.end(net.sim_elapsed_s(), net.total_bytes());

    // ---- Step 2 (paper Step ❷): masking + secure aggregation ------------
    // Users are independent: their masking shares run concurrently (one
    // lane per user), and the backend nests per-P-block panel parallelism
    // inside each share. Results land in index-addressed slots, so the
    // schedule cannot affect the output.
    metrics.begin("step2: mask + secagg", net.sim_elapsed_s(), net.total_bytes());
    let shares: Vec<Mat> =
        run_parallel_collect(backend, k_users, |i| match (&p_mask, &q_slices[i]) {
            (MaskRep::Block(p), QSliceRep::Block(qi)) => {
                mask_share_block(p, &parts[i], qi, backend)
            }
            (MaskRep::Dense(p), QSliceRep::Dense(qi)) => backend
                .matmul(p, &parts[i])
                .and_then(|px| backend.matmul(&px, qi)),
            _ => Err(Error::Protocol("mask representation mismatch".into())),
        })?;

    // a single-user federation has no pairwise masks to agree on (DH
    // setup needs ≥ 2 parties); its one share still passes through the
    // same fixed-point codec so k = 1 results match any k ≥ 2 run
    let group = if k_users == 1 {
        SecAggGroup::from_seeds(vec![vec![0u64]])?
    } else {
        SecAggGroup::setup(&user_ids, CSP, &mut net, &mut rng)?
    };
    let batch_rows = if cfg.opts.minibatch_secagg {
        cfg.secagg_batch_rows.max(1)
    } else {
        m // whole-matrix aggregation (Opt2 off)
    };
    let x_masked = minibatch::aggregate_matrices(
        &group,
        &shares,
        batch_rows,
        &user_ids,
        CSP,
        &mut net,
        &mut metrics,
        backend,
    )?;
    metrics.end(net.sim_elapsed_s(), net.total_bytes());

    // ---- Step 3 (paper Step ❸): CSP runs a standard SVD ----------------
    metrics.begin("step3: CSP svd", net.sim_elapsed_s(), net.total_bytes());
    let csp_svd = match cfg.mode {
        SvdMode::Full => svd(&x_masked)?,
        SvdMode::Truncated { rank } => {
            let (oversample, power_iters) = truncated_svd_tuning(rank);
            // derived (not drawn from the ambient rng) so the cluster CSP
            // consumes the very same probe stream — see step3_probe_seed
            randomized_svd(
                &x_masked,
                rank,
                oversample,
                power_iters,
                step3_probe_seed(cfg.seed),
            )?
        }
    };
    metrics.end(net.sim_elapsed_s(), net.total_bytes());

    // ---- Step 4 (paper Step ❹): result delivery + mask removal ---------
    metrics.begin("step4: recover results", net.sim_elapsed_s(), net.total_bytes());
    let ksv = csp_svd.s.len();

    let u = if cfg.recover_u {
        // CSP broadcasts U' and Σ to every user
        let payload = (m * ksv * 8 + ksv * 8) as u64;
        net.begin_round();
        for &uid in &user_ids {
            net.send(CSP, uid, payload);
        }
        net.end_round();
        Some(p_mask.transpose_mul_with(&csp_svd.u, backend)?)
    } else {
        None
    };

    let mut v_parts = Vec::new();
    if cfg.recover_v {
        // Σ still needs to reach users even without U
        if !cfg.recover_u {
            net.begin_round();
            for &uid in &user_ids {
                net.send(CSP, uid, (ksv * 8) as u64);
            }
            net.end_round();
        }
        for (i, qs) in q_slices.iter().enumerate() {
            match qs {
                QSliceRep::Block(qi) => {
                    let (ri, blinded_q) = v_recovery::blind_qit(qi, &mut rng)?;
                    net.send(user_ids[i], CSP, blinded_q.payload_bytes());
                    let blinded_v = v_recovery::csp_blind_vit(&csp_svd.vt, &blinded_q, backend)?;
                    net.send(
                        CSP,
                        user_ids[i],
                        (blinded_v.rows() * blinded_v.cols() * 8) as u64,
                    );
                    v_parts.push(v_recovery::unblind_vit(&blinded_v, &ri)?);
                }
                QSliceRep::Dense(qi) => {
                    // Opt1-off path: dense Rᵢ (O(nᵢ³) — the cost the paper's
                    // block Rᵢ removes). Functionally identical.
                    let ni = qi.rows();
                    let ri = loop {
                        let cand = Mat::gaussian(ni, ni, &mut rng);
                        if crate::linalg::lu::lu_decompose(&cand).is_ok() {
                            break cand;
                        }
                    };
                    let blinded_q = qi.transpose().mul(&ri)?;
                    net.send(user_ids[i], CSP, (n * ni * 8) as u64);
                    let blinded_v = backend.matmul(&csp_svd.vt, &blinded_q)?;
                    net.send(CSP, user_ids[i], (ksv * ni * 8) as u64);
                    let ri_inv = crate::linalg::lu::inverse(&ri)?;
                    v_parts.push(blinded_v.mul(&ri_inv)?);
                }
            }
        }
    }
    metrics.end(net.sim_elapsed_s(), net.total_bytes());

    Ok(FedSvdOutput {
        u,
        s: csp_svd.s.clone(),
        v_parts,
        csp_svd,
        p_mask,
        q_slices,
        metrics,
        net,
    })
}

/// One user's Step-2 product `P·Xᵢ·Qᵢ` through the backend's fused
/// masking op — the hot loop of the whole protocol. Per P-block: the
/// `P_b·Xᵢ` panel lands in a reused per-lane scratch buffer and is
/// scattered through `Qᵢ`'s pieces straight into the output's disjoint
/// row panel. Zero per-block heap allocations; panels run concurrently.
fn mask_share_block(
    p: &BlockDiagMat,
    xi: &Mat,
    qi: &BlockDiagSlice,
    backend: &dyn GemmBackend,
) -> Result<Mat> {
    let mut out = Mat::zeros(xi.rows(), qi.cols());
    let pieces = qi.scatter_pieces();
    backend.mask_apply_into(p.starts(), p.blocks(), xi, &pieces, &mut out)?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::protocol::split_columns;
    use crate::util::{max_abs_diff, rmse};

    fn join(parts: &[Mat]) -> Mat {
        let mut x = parts[0].clone();
        for p in &parts[1..] {
            x = x.hcat(p).unwrap();
        }
        x
    }

    /// Compare singular subspaces up to per-vector sign.
    fn aligned_diff(a: &Mat, b: &Mat, cols: bool) -> f64 {
        // a, b hold vectors along `cols ? columns : rows`
        let k = if cols { a.cols() } else { a.rows() };
        let mut worst = 0.0f64;
        for i in 0..k {
            let (va, vb): (Vec<f64>, Vec<f64>) = if cols {
                (a.col(i), b.col(i))
            } else {
                (a.row(i).to_vec(), b.row(i).to_vec())
            };
            let dot: f64 = va.iter().zip(&vb).map(|(x, y)| x * y).sum();
            let sign = if dot >= 0.0 { 1.0 } else { -1.0 };
            let d = va
                .iter()
                .zip(&vb)
                .map(|(x, y)| (x - sign * y).abs())
                .fold(0.0f64, f64::max);
            worst = worst.max(d);
        }
        worst
    }

    fn check_lossless(m: usize, widths: &[usize], cfg: &FedSvdConfig) {
        let mut rng = Xoshiro256::seed_from_u64(99);
        let parts: Vec<Mat> = widths.iter().map(|&w| Mat::gaussian(m, w, &mut rng)).collect();
        let x = join(&parts);
        let out = run_fedsvd(&parts, cfg).unwrap();
        let truth = svd(&x).unwrap();

        // singular values match to machine precision (relative)
        for (i, (a, b)) in out.s.iter().zip(&truth.s).enumerate() {
            assert!(
                (a - b).abs() <= 1e-9 * truth.s[0],
                "σ{i}: {a} vs {b}"
            );
        }
        // singular vectors match up to sign
        let u = out.u.as_ref().unwrap();
        assert!(aligned_diff(u, &truth.u, true) < 1e-8, "U mismatch");
        let v_joined = {
            let mut vj = out.v_parts[0].clone();
            for p in &out.v_parts[1..] {
                vj = vj.hcat(p).unwrap();
            }
            vj
        };
        assert!(aligned_diff(&v_joined, &truth.vt, false) < 1e-8, "V mismatch");

        // reconstruction through the recovered factors
        let rec = SvdResult {
            u: u.clone(),
            s: out.s.clone(),
            vt: v_joined,
        }
        .reconstruct();
        let err = rmse(rec.data(), x.data());
        assert!(err < 1e-10, "reconstruction rmse {err}");
    }

    #[test]
    fn lossless_two_users_default() {
        let cfg = FedSvdConfig {
            block_size: 5,
            secagg_batch_rows: 4,
            ..Default::default()
        };
        check_lossless(12, &[7, 6], &cfg);
    }

    #[test]
    fn lossless_single_user_federation() {
        // degenerate k = 1: no pairwise secagg masks, same codec path
        let cfg = FedSvdConfig {
            block_size: 4,
            secagg_batch_rows: 8,
            ..Default::default()
        };
        check_lossless(10, &[6], &cfg);
    }

    #[test]
    fn lossless_three_users_ragged() {
        let cfg = FedSvdConfig {
            block_size: 4,
            secagg_batch_rows: 16,
            ..Default::default()
        };
        check_lossless(10, &[5, 3, 7], &cfg);
    }

    #[test]
    fn lossless_wide_matrix() {
        let cfg = FedSvdConfig {
            block_size: 6,
            ..Default::default()
        };
        check_lossless(8, &[9, 8], &cfg);
    }

    #[test]
    fn lossless_without_block_opt() {
        let cfg = FedSvdConfig {
            opts: OptFlags {
                block_masks: false,
                minibatch_secagg: false,
            },
            ..Default::default()
        };
        check_lossless(9, &[4, 5], &cfg);
    }

    #[test]
    fn masked_matrix_reaches_csp_not_raw() {
        // the CSP-side input differs from X (masking works) yet has the
        // same singular values (Thm 1)
        let mut rng = Xoshiro256::seed_from_u64(5);
        let parts = split_columns(&Mat::gaussian(8, 10, &mut rng), 2).unwrap();
        let x = join(&parts);
        let out = run_fedsvd(&parts, &FedSvdConfig { block_size: 4, ..Default::default() })
            .unwrap();
        let truth = svd(&x).unwrap();
        for (a, b) in out.csp_svd.s.iter().zip(&truth.s) {
            assert!((a - b).abs() < 1e-9 * truth.s[0]);
        }
        // but the masked factors differ from the raw ones
        assert!(max_abs_diff(out.csp_svd.u.data(), truth.u.data()) > 1e-3);
    }

    /// Decaying-spectrum matrix (what PCA/LSA workloads look like; flat
    /// Gaussian spectra are the adversarial case for randomized SVD).
    fn decaying_matrix(m: usize, n: usize, seed: u64) -> Mat {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let k = m.min(n);
        let mut a = Mat::gaussian(m, k, &mut rng);
        for j in 0..k {
            let s = 1.0 / (1.0 + j as f64).powf(1.2);
            for i in 0..m {
                a[(i, j)] *= s;
            }
        }
        let b = Mat::gaussian(k, n, &mut rng);
        a.mul(&b).unwrap()
    }

    #[test]
    fn truncated_mode_returns_top_r() {
        let parts = split_columns(&decaying_matrix(20, 12, 6), 2).unwrap();
        let cfg = FedSvdConfig {
            block_size: 5,
            mode: SvdMode::Truncated { rank: 3 },
            recover_v: true,
            ..Default::default()
        };
        let out = run_fedsvd(&parts, &cfg).unwrap();
        assert_eq!(out.s.len(), 3);
        assert_eq!(out.u.as_ref().unwrap().cols(), 3);
        assert_eq!(out.v_parts[0].rows(), 3);
        let truth = svd(&join(&parts)).unwrap();
        for i in 0..3 {
            assert!((out.s[i] - truth.s[i]).abs() < 1e-6 * truth.s[0]);
        }
    }

    #[test]
    fn network_is_metered() {
        let mut rng = Xoshiro256::seed_from_u64(7);
        let parts = split_columns(&Mat::gaussian(6, 8, &mut rng), 2).unwrap();
        let out = run_fedsvd(&parts, &FedSvdConfig { block_size: 4, ..Default::default() })
            .unwrap();
        assert!(out.net.total_bytes() > 0);
        assert!(out.net.sim_elapsed_s() > 0.0);
        assert!(out.metrics.phases().len() == 4);
        // TA must never receive anything (paper §3.5: "TA receives nothing")
        assert_eq!(out.net.party(TA).bytes_received, 0);
    }

    #[test]
    fn block_opt_reduces_communication() {
        let mut rng = Xoshiro256::seed_from_u64(8);
        let parts = split_columns(&Mat::gaussian(24, 24, &mut rng), 2).unwrap();
        let on = run_fedsvd(
            &parts,
            &FedSvdConfig { block_size: 4, ..Default::default() },
        )
        .unwrap();
        let off = run_fedsvd(
            &parts,
            &FedSvdConfig {
                block_size: 4,
                opts: OptFlags {
                    block_masks: false,
                    minibatch_secagg: true,
                },
                ..Default::default()
            },
        )
        .unwrap();
        assert!(
            on.net.total_bytes() < off.net.total_bytes(),
            "block masks should cut mask-delivery bytes ({} vs {})",
            on.net.total_bytes(),
            off.net.total_bytes()
        );
    }

    #[test]
    fn recover_flags_control_outputs() {
        let mut rng = Xoshiro256::seed_from_u64(9);
        let parts = split_columns(&Mat::gaussian(6, 6, &mut rng), 2).unwrap();
        let cfg = FedSvdConfig {
            block_size: 3,
            recover_u: false,
            recover_v: false,
            ..Default::default()
        };
        let out = run_fedsvd(&parts, &cfg).unwrap();
        assert!(out.u.is_none());
        assert!(out.v_parts.is_empty());
        assert!(!out.s.is_empty());
    }

    #[test]
    fn rejects_degenerate_inputs() {
        assert!(run_fedsvd(&[], &FedSvdConfig::default()).is_err());
        let a = Mat::zeros(3, 2);
        let b = Mat::zeros(4, 2);
        assert!(run_fedsvd(&[a, b], &FedSvdConfig::default()).is_err());
    }
}
