//! Federated recovery of `Vᵢᵀ` (paper §3.3, Eq. 6–7).
//!
//! The CSP may not broadcast `V'ᵀ` (users hold `Qᵢ` and could unmask other
//! users' eigenvectors), and users may not reveal `Qᵢᵀ` to the CSP. The
//! paper's two-sided blinding:
//!
//! ```text
//! user i:  [Qᵢᵀ]ᴿ = Qᵢᵀ·Rᵢ          (Rᵢ block-diagonal random, Eq. 7)
//! CSP:     [Vᵢᵀ]ᴿ = V'ᵀ·[Qᵢᵀ]ᴿ
//! user i:  Vᵢᵀ    = [Vᵢᵀ]ᴿ·Rᵢ⁻¹
//! ```
//!
//! `Rᵢ`'s block sizes follow `Qᵢ`'s piece extents so `QᵢᵀRᵢ` stays sparse:
//! computing it is O(nᵢ·b²) = O(nᵢ) and inverting `Rᵢ` is O(nᵢ·b²) too.

use crate::linalg::{GemmBackend, Mat};
use crate::mask::block_diag::{BlockDiagMat, BlockDiagSlice};
use crate::rng::Xoshiro256;
use crate::util::{Error, Result};

/// User-side step 1: draw `Rᵢ` matching `qi`'s piece structure and blind
/// `Qᵢᵀ`. Returns `(Rᵢ, [Qᵢᵀ]ᴿ)`.
pub fn blind_qit(
    qi: &BlockDiagSlice,
    rng: &mut Xoshiro256,
) -> Result<(BlockDiagMat, BlockDiagSlice)> {
    let extents = qi.piece_row_extents();
    if extents.is_empty() {
        return Err(Error::Protocol("blind_qit: empty slice".into()));
    }
    // Gaussian blocks are invertible w.p. 1; retry on numerical degeneracy.
    let ri = loop {
        let blocks: Vec<Mat> = extents
            .iter()
            .map(|&e| Mat::gaussian(e, e, rng))
            .collect();
        let cand = BlockDiagMat::from_blocks(blocks)?;
        if cand.inverse().is_ok() {
            break cand;
        }
    };
    let blinded = qi.transpose_mul_blockdiag(&ri)?;
    Ok((ri, blinded))
}

/// CSP-side step 2: `[Vᵢᵀ]ᴿ = V'ᵀ·[Qᵢᵀ]ᴿ` (dense k×n · sparse n×nᵢ).
/// Each sparse piece view-multiplies the matching `V'ᵀ` column window and
/// accumulates into the output's global columns — no temporaries.
pub fn csp_blind_vit(
    vt_masked: &Mat,
    blinded_qit: &BlockDiagSlice,
    backend: &dyn GemmBackend,
) -> Result<Mat> {
    if vt_masked.cols() != blinded_qit.rows() {
        return Err(Error::Shape(format!(
            "csp_blind_vit: V'ᵀ is {}x{}, [Qᵢᵀ]ᴿ has {} rows",
            vt_masked.rows(),
            vt_masked.cols(),
            blinded_qit.rows()
        )));
    }
    let mut out = Mat::zeros(vt_masked.rows(), blinded_qit.cols());
    for p in blinded_qit.pieces() {
        backend.gemm_view_acc(
            1.0,
            vt_masked.view(0, vt_masked.rows(), p.local_row, p.local_row + p.mat.rows()),
            p.mat.as_view(),
            &mut out,
            0,
            p.global_col,
        )?;
    }
    Ok(out)
}

/// User-side step 3: strip the blinding, `Vᵢᵀ = [Vᵢᵀ]ᴿ·Rᵢ⁻¹`.
pub fn unblind_vit(blinded_vit: &Mat, ri: &BlockDiagMat) -> Result<Mat> {
    if blinded_vit.cols() != ri.dim() {
        return Err(Error::Shape(format!(
            "unblind_vit: [Vᵢᵀ]ᴿ is {}x{}, Rᵢ dim {}",
            blinded_vit.rows(),
            blinded_vit.cols(),
            ri.dim()
        )));
    }
    let ri_inv = ri.inverse()?;
    ri_inv.rmul_dense(blinded_vit)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{matmul, CpuBackend};
    use crate::mask::orthogonal::block_orthogonal;
    use crate::util::max_abs_diff;

    /// End-to-end Eq. 6 check: the three-step dance returns exactly
    /// V'ᵀ·Qᵢᵀ (which equals Vᵢᵀ when V'ᵀ is the masked right factor).
    #[test]
    fn recovery_roundtrip_equals_direct_product() {
        let mut rng = Xoshiro256::seed_from_u64(1);
        let n = 12;
        let q = block_orthogonal(n, 4, 7).unwrap();
        let qi = q.row_slice(3, 9).unwrap(); // user owns cols 3..9
        let vt_masked = Mat::gaussian(5, n, &mut rng); // stand-in for V'ᵀ

        let (ri, blinded_q) = blind_qit(&qi, &mut rng).unwrap();
        let blinded_v = csp_blind_vit(&vt_masked, &blinded_q, CpuBackend::global()).unwrap();
        let vit = unblind_vit(&blinded_v, &ri).unwrap();

        let direct = matmul(&vt_masked, &qi.to_dense().transpose()).unwrap();
        assert!(
            max_abs_diff(vit.data(), direct.data()) < 1e-9,
            "diff {}",
            max_abs_diff(vit.data(), direct.data())
        );
        assert_eq!(vit.shape(), (5, 6));
    }

    #[test]
    fn blinded_q_differs_from_plain_q() {
        let mut rng = Xoshiro256::seed_from_u64(2);
        let q = block_orthogonal(8, 4, 3).unwrap();
        let qi = q.row_slice(0, 4).unwrap();
        let (_ri, blinded) = blind_qit(&qi, &mut rng).unwrap();
        let plain_t = qi.to_dense().transpose();
        let d = max_abs_diff(blinded.to_dense().data(), plain_t.data());
        assert!(d > 1e-2, "blinding changed nothing (diff {d})");
    }

    #[test]
    fn blinding_is_randomized() {
        let mut rng = Xoshiro256::seed_from_u64(3);
        let q = block_orthogonal(6, 3, 4).unwrap();
        let qi = q.row_slice(0, 6).unwrap();
        let (_, b1) = blind_qit(&qi, &mut rng).unwrap();
        let (_, b2) = blind_qit(&qi, &mut rng).unwrap();
        assert!(max_abs_diff(b1.to_dense().data(), b2.to_dense().data()) > 1e-3);
    }

    #[test]
    fn csp_never_sees_unblinded_q() {
        // structural check: csp step consumes only the blinded slice type
        // and the masked V — compile-time guarantee; here we verify the
        // sparse product matches its dense equivalent.
        let mut rng = Xoshiro256::seed_from_u64(4);
        let q = block_orthogonal(10, 5, 5).unwrap();
        let qi = q.row_slice(2, 8).unwrap();
        let (_ri, blinded) = blind_qit(&qi, &mut rng).unwrap();
        let vt = Mat::gaussian(4, 10, &mut rng);
        let fast = csp_blind_vit(&vt, &blinded, CpuBackend::global()).unwrap();
        let slow = matmul(&vt, &blinded.to_dense()).unwrap();
        assert!(max_abs_diff(fast.data(), slow.data()) < 1e-11);
    }

    #[test]
    fn shape_errors() {
        let mut rng = Xoshiro256::seed_from_u64(5);
        let q = block_orthogonal(6, 3, 6).unwrap();
        let qi = q.row_slice(0, 3).unwrap();
        let (ri, blinded) = blind_qit(&qi, &mut rng).unwrap();
        // V'ᵀ with wrong width
        let bad_vt = Mat::zeros(4, 5);
        assert!(csp_blind_vit(&bad_vt, &blinded, CpuBackend::global()).is_err());
        // blinded V with wrong width vs Rᵢ
        assert!(unblind_vit(&Mat::zeros(4, 5), &ri).is_err());
    }
}
