//! Privacy machinery (paper §3.5).
//!
//! * Theorem 2 witness: given a masked matrix `X' = P₁X₁Q₁`, construct a
//!   *different* plausible raw matrix `X₂` (with its own masks) such that
//!   `P₂X₂Q₂ = X'` exactly — the CSP cannot identify the real data.
//! * First/second-moment randomness checks used to sanity-check that the
//!   blinded `[Qᵢᵀ]ᴿ` shipped to the CSP is statistically unstructured
//!   (the formal claim is computational indistinguishability per Zhang
//!   et al. [26]; the moments are the testable corollary).

use crate::linalg::{svd, Mat};
use crate::mask::orthogonal::random_orthogonal;
use crate::rng::Xoshiro256;
use crate::util::Result;

/// A Theorem-2 witness: alternative `(P₂, X₂, Q₂)` with `P₂X₂Q₂ = X'`.
pub struct AlternativeExplanation {
    pub p2: Mat,
    pub x2: Mat,
    pub q2: Mat,
}

/// Construct the Theorem-2 witness for a masked matrix `x_masked`.
///
/// Following the paper's proof: write X' = U'ΣV'ᵀ, draw random orthogonal
/// R₁ (m×m), R₂ (n×n) and set
///   X₂ = R₁ᵀ Σ R₂ᵀ,   P₂ = U' R₁,   Q₂ = R₂ V'ᵀ
/// so that P₂X₂Q₂ = U'ΣV'ᵀ = X'. Each choice of (R₁,R₂) gives a distinct
/// "raw" matrix explaining the same observation — infinitely many in ℝ.
pub fn alternative_explanation(
    x_masked: &Mat,
    rng: &mut Xoshiro256,
) -> Result<AlternativeExplanation> {
    let (m, n) = x_masked.shape();
    let f = svd(x_masked)?;
    let k = f.s.len();
    let r1 = random_orthogonal(m, rng)?;
    let r2 = random_orthogonal(n, rng)?;

    // Σ as m×n rectangular diagonal
    let sigma = Mat::diag(m, n, &f.s);
    // complete U' to m×m and V'ᵀ to n×n so P₂/Q₂ are orthogonal:
    // svd() returns thin factors; complete via the orthonormal-basis trick
    let u_full = complete_square(&f.u, m, k, rng)?;
    let vt_full = complete_square(&f.vt.transpose(), n, k, rng)?.transpose();

    let x2 = r1.t_mul(&sigma)?.mul(&r2.transpose())?;
    let p2 = u_full.mul(&r1)?;
    let q2 = r2.mul(&vt_full)?;
    Ok(AlternativeExplanation { p2, x2, q2 })
}

/// Complete an m×k column-orthonormal matrix to a full m×m orthogonal one.
fn complete_square(u: &Mat, m: usize, k: usize, rng: &mut Xoshiro256) -> Result<Mat> {
    if k >= m {
        return Ok(u.take_cols(m));
    }
    let mut out = Mat::zeros(m, m);
    out.set_slice(0, 0, u);
    for j in k..m {
        'probe: for _ in 0..64 {
            let mut v: Vec<f64> = (0..m).map(|_| rng.next_gaussian()).collect();
            for _pass in 0..2 {
                for jj in 0..j {
                    let mut dot = 0.0;
                    for i in 0..m {
                        dot += out[(i, jj)] * v[i];
                    }
                    for i in 0..m {
                        let o = out[(i, jj)];
                        v[i] -= dot * o;
                    }
                }
            }
            let norm = v.iter().map(|x| x * x).sum::<f64>().sqrt();
            if norm > 1e-8 {
                for i in 0..m {
                    out[(i, j)] = v[i] / norm;
                }
                break 'probe;
            }
        }
    }
    Ok(out)
}

/// Simple randomness report on a matrix's entries: mean, variance, and
/// lag-1 autocorrelation (row-major order).
#[derive(Debug, Clone, Copy)]
pub struct MomentReport {
    pub mean: f64,
    pub variance: f64,
    pub lag1_autocorr: f64,
}

/// Compute moments of a matrix's entries.
pub fn moment_report(x: &Mat) -> MomentReport {
    let d = x.data();
    let n = d.len() as f64;
    let mean = d.iter().sum::<f64>() / n;
    let variance = d.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / n;
    let mut cov = 0.0;
    for w in d.windows(2) {
        cov += (w[0] - mean) * (w[1] - mean);
    }
    let lag1 = if variance > 0.0 {
        (cov / (n - 1.0)) / variance
    } else {
        0.0
    };
    MomentReport {
        mean,
        variance,
        lag1_autocorr: lag1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matmul;
    use crate::mask::orthogonal::block_orthogonal;
    use crate::util::max_abs_diff;

    #[test]
    fn theorem2_witness_reproduces_masked_matrix() {
        let mut rng = Xoshiro256::seed_from_u64(1);
        // build a real masked matrix first
        let x1 = Mat::gaussian(6, 8, &mut rng);
        let p1 = block_orthogonal(6, 3, 11).unwrap();
        let q1 = block_orthogonal(8, 4, 12).unwrap();
        let x_masked = q1.rmul_dense(&p1.mul_dense(&x1).unwrap()).unwrap();

        let alt = alternative_explanation(&x_masked, &mut rng).unwrap();
        let recon = matmul(&matmul(&alt.p2, &alt.x2).unwrap(), &alt.q2).unwrap();
        let d = max_abs_diff(recon.data(), x_masked.data());
        assert!(d < 1e-8, "witness mismatch {d}");
        // the alternative "raw" matrix is nothing like the real one
        assert!(max_abs_diff(alt.x2.data(), x1.data()) > 1e-2);
    }

    #[test]
    fn theorem2_masks_are_orthogonal() {
        let mut rng = Xoshiro256::seed_from_u64(2);
        let x_masked = Mat::gaussian(5, 7, &mut rng);
        let alt = alternative_explanation(&x_masked, &mut rng).unwrap();
        assert!(alt.p2.orthonormality_defect() < 1e-8, "P₂ defect");
        assert!(
            alt.q2.transpose().orthonormality_defect() < 1e-8,
            "Q₂ defect"
        );
    }

    #[test]
    fn distinct_witnesses_for_same_observation() {
        let mut rng = Xoshiro256::seed_from_u64(3);
        let x_masked = Mat::gaussian(4, 5, &mut rng);
        let a = alternative_explanation(&x_masked, &mut rng).unwrap();
        let b = alternative_explanation(&x_masked, &mut rng).unwrap();
        assert!(max_abs_diff(a.x2.data(), b.x2.data()) > 1e-3);
    }

    #[test]
    fn moment_report_of_gaussian() {
        let mut rng = Xoshiro256::seed_from_u64(4);
        let x = Mat::gaussian(100, 100, &mut rng);
        let r = moment_report(&x);
        assert!(r.mean.abs() < 0.02);
        assert!((r.variance - 1.0).abs() < 0.05);
        assert!(r.lag1_autocorr.abs() < 0.05);
    }

    #[test]
    fn moment_report_flags_structure() {
        // a strongly structured matrix has high lag-1 autocorrelation
        let x = Mat::from_fn(50, 50, |i, j| (i * 50 + j) as f64);
        let r = moment_report(&x);
        assert!(r.lag1_autocorr > 0.9);
    }
}
