//! Horizontally-partitioned FedSVD (paper §2.1).
//!
//! "One type of partition could be easily transferred to another through
//! matrix transpose in SVD." Horizontal partition: parties share the
//! feature space (columns) and own disjoint *sample rows*
//! `X = [X₁; X₂; …; X_k]` (stacked vertically). Transposing swaps the
//! roles of U and V: run the vertical protocol on `Xᵀ = [X₁ᵀ … X_kᵀ]`,
//! then the *shared* factor is V (right singular vectors of X) and each
//! party's *secret* factor is its own slice of U.

use super::fedsvd::{run_fedsvd_with_backend, FedSvdConfig, FedSvdOutput};
use crate::linalg::{CpuBackend, GemmBackend, Mat};
use crate::util::{Error, Result};

/// Result of the horizontal protocol, expressed in the original (row-
/// partitioned) orientation.
pub struct HorizontalOutput {
    /// Shared right factor Vᵀ (k×n) — the paper's "shared results" swap
    /// roles under transposition.
    pub vt: Option<Mat>,
    /// Shared singular values (identical to the vertical run's).
    pub s: Vec<f64>,
    /// Per-user secret left factors: user i's rows of U (mᵢ×k).
    pub u_parts: Vec<Mat>,
    /// Underlying (transposed-orientation) protocol output with all
    /// meters and masks.
    pub inner: FedSvdOutput,
}

/// Run FedSVD over horizontally-partitioned parts `[X₁; …; X_k]`
/// (each mᵢ×n, same n).
pub fn run_fedsvd_horizontal(
    parts: &[Mat],
    cfg: &FedSvdConfig,
) -> Result<HorizontalOutput> {
    run_fedsvd_horizontal_with_backend(parts, cfg, CpuBackend::global())
}

/// Backend-parameterized variant (CPU pool or PJRT tiles).
pub fn run_fedsvd_horizontal_with_backend(
    parts: &[Mat],
    cfg: &FedSvdConfig,
    backend: &dyn GemmBackend,
) -> Result<HorizontalOutput> {
    if parts.is_empty() {
        return Err(Error::Protocol("horizontal: no users".into()));
    }
    let n = parts[0].cols();
    for p in parts {
        if p.cols() != n {
            return Err(Error::Shape(
                "horizontal: users disagree on feature width".into(),
            ));
        }
    }
    // transpose each part: user-i's rows become columns
    let t_parts: Vec<Mat> = parts.iter().map(|p| p.transpose()).collect();
    let out = run_fedsvd_with_backend(&t_parts, cfg, backend)?;

    // map back: vertical-run U is our V (shared), vertical-run Vᵢᵀ (k×mᵢ)
    // transposes to user-i's U slice (mᵢ×k)
    let vt = out.u.as_ref().map(|u| u.transpose());
    let u_parts = out
        .v_parts
        .iter()
        .map(|vit| vit.transpose())
        .collect::<Vec<_>>();
    Ok(HorizontalOutput {
        vt,
        s: out.s.clone(),
        u_parts,
        inner: out,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{svd, SvdResult};
    use crate::rng::Xoshiro256;
    use crate::util::rmse;

    fn stack(parts: &[Mat]) -> Mat {
        let mut x = parts[0].clone();
        for p in &parts[1..] {
            x = x.vcat(p).unwrap();
        }
        x
    }

    fn cfg() -> FedSvdConfig {
        FedSvdConfig {
            block_size: 6,
            secagg_batch_rows: 16,
            ..Default::default()
        }
    }

    #[test]
    fn horizontal_is_lossless() {
        let mut rng = Xoshiro256::seed_from_u64(1);
        // three hospitals with 7/5/8 patients over 12 shared features
        let parts = vec![
            Mat::gaussian(7, 12, &mut rng),
            Mat::gaussian(5, 12, &mut rng),
            Mat::gaussian(8, 12, &mut rng),
        ];
        let x = stack(&parts);
        let out = run_fedsvd_horizontal(&parts, &cfg()).unwrap();
        let truth = svd(&x).unwrap();

        assert!(rmse(&out.s, &truth.s) < 1e-9 * truth.s[0]);
        // reconstruction through the mapped-back factors
        let u_joined = {
            let mut u = out.u_parts[0].clone();
            for p in &out.u_parts[1..] {
                u = u.vcat(p).unwrap();
            }
            u
        };
        let rec = SvdResult {
            u: u_joined,
            s: out.s.clone(),
            vt: out.vt.clone().unwrap(),
        }
        .reconstruct();
        assert!(rmse(rec.data(), x.data()) < 1e-10);
    }

    #[test]
    fn u_parts_have_user_row_counts() {
        let mut rng = Xoshiro256::seed_from_u64(2);
        let parts = vec![Mat::gaussian(4, 9, &mut rng), Mat::gaussian(6, 9, &mut rng)];
        let out = run_fedsvd_horizontal(&parts, &cfg()).unwrap();
        assert_eq!(out.u_parts[0].rows(), 4);
        assert_eq!(out.u_parts[1].rows(), 6);
        assert_eq!(out.vt.as_ref().unwrap().cols(), 9);
    }

    #[test]
    fn horizontal_matches_vertical_on_transpose() {
        // σ of X and Xᵀ coincide — the two partition modes agree
        let mut rng = Xoshiro256::seed_from_u64(3);
        let parts_h = vec![Mat::gaussian(5, 8, &mut rng), Mat::gaussian(5, 8, &mut rng)];
        let x = stack(&parts_h);
        let out_h = run_fedsvd_horizontal(&parts_h, &cfg()).unwrap();
        let parts_v = crate::protocol::split_columns(&x, 2).unwrap();
        let out_v = crate::protocol::run_fedsvd(&parts_v, &cfg()).unwrap();
        assert!(rmse(&out_h.s, &out_v.s) < 1e-10 * out_v.s[0].max(1.0));
    }

    #[test]
    fn rejects_ragged_feature_width() {
        let parts = vec![Mat::zeros(3, 5), Mat::zeros(3, 6)];
        assert!(run_fedsvd_horizontal(&parts, &cfg()).is_err());
        assert!(run_fedsvd_horizontal(&[], &cfg()).is_err());
    }
}
