//! Byte-metered link simulation with a round-structured latency model.

use std::collections::HashMap;

/// A logical protocol participant (TA, CSP, or user-i).
pub type PartyId = usize;

/// Reserved ids used by the FedSVD protocol wiring.
pub const TA: PartyId = 0;
pub const CSP: PartyId = 1;
/// First user id; user-i is `USER_BASE + i`.
pub const USER_BASE: PartyId = 2;

/// Bandwidth/latency of every (symmetric) link in the star topology.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkSpec {
    /// Bits per second.
    pub bandwidth_bps: f64,
    /// Round-trip time in seconds.
    pub rtt_s: f64,
}

impl Default for LinkSpec {
    fn default() -> Self {
        super::presets::paper_default()
    }
}

/// Per-party transfer counters.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct TransferStats {
    pub bytes_sent: u64,
    pub bytes_received: u64,
    pub messages: u64,
}

/// The in-process network simulator.
///
/// Usage: wrap each batch of logically-concurrent messages in
/// [`NetSim::begin_round`] / [`NetSim::end_round`]; `send` meters bytes.
/// Messages outside an explicit round are treated as their own round.
///
/// Rounds nest: `begin_round`/`end_round` pairs are depth-counted, and an
/// inner pair merges its messages into the outermost open round (they are
/// logically concurrent with it). The round only closes — and its cost is
/// only charged — when the depth returns to zero. The explicit counter is
/// the nesting guard: an unmatched `end_round` panics instead of silently
/// corrupting the accounting, and [`NetSim::round_depth`] lets callers
/// assert their bracketing. Nesting exists for composability: protocol
/// helpers that bracket their own sends (or concurrent senders that each
/// bracket, as in the tests below) can run under a round someone else —
/// e.g. the cluster round scheduler — already opened, instead of
/// panicking or silently splitting the round.
#[derive(Debug, Default)]
pub struct NetSim {
    spec: LinkSpec,
    per_party: HashMap<PartyId, TransferStats>,
    total_bytes: u64,
    total_messages: u64,
    rounds: u64,
    sim_elapsed_s: f64,
    // open-round state
    round_depth: u32,
    round_max_bytes: u64,
    /// per-(sender) bytes in the open round (concurrent senders overlap)
    round_sender_bytes: HashMap<PartyId, u64>,
}

impl NetSim {
    pub fn new(spec: LinkSpec) -> Self {
        Self {
            spec,
            ..Default::default()
        }
    }

    pub fn spec(&self) -> LinkSpec {
        self.spec
    }

    /// Start a group of concurrent messages. Nested calls join the
    /// outermost open round (depth-counted); see the type docs.
    pub fn begin_round(&mut self) {
        if self.round_depth == 0 {
            self.round_max_bytes = 0;
            self.round_sender_bytes.clear();
        }
        self.round_depth += 1;
    }

    /// Close one nesting level; at depth zero the round is charged as
    /// `max-per-sender bytes / bw + RTT`.
    pub fn end_round(&mut self) {
        assert!(self.round_depth > 0, "end_round: no open round");
        self.round_depth -= 1;
        if self.round_depth > 0 {
            return; // inner bracket: stays merged into the outer round
        }
        self.rounds += 1;
        let max_bytes = self
            .round_sender_bytes
            .values()
            .cloned()
            .max()
            .unwrap_or(0)
            .max(self.round_max_bytes);
        self.sim_elapsed_s += max_bytes as f64 * 8.0 / self.spec.bandwidth_bps + self.spec.rtt_s;
    }

    /// Current `begin_round` nesting depth (0 = no open round).
    pub fn round_depth(&self) -> u32 {
        self.round_depth
    }

    /// Meter one message of `bytes` from `from` to `to`.
    pub fn send(&mut self, from: PartyId, to: PartyId, bytes: u64) {
        let implicit = self.round_depth == 0;
        if implicit {
            self.begin_round();
        }
        self.total_bytes += bytes;
        self.total_messages += 1;
        self.per_party.entry(from).or_default().bytes_sent += bytes;
        self.per_party.entry(from).or_default().messages += 1;
        self.per_party.entry(to).or_default().bytes_received += bytes;
        *self.round_sender_bytes.entry(from).or_insert(0) += bytes;
        if implicit {
            self.end_round();
        }
    }

    /// Meter a broadcast (same payload to many receivers; sender serializes).
    pub fn broadcast(&mut self, from: PartyId, tos: &[PartyId], bytes: u64) {
        let implicit = self.round_depth == 0;
        if implicit {
            self.begin_round();
        }
        for &to in tos {
            self.send(from, to, bytes);
        }
        if implicit {
            self.end_round();
        }
    }

    pub fn total_bytes(&self) -> u64 {
        self.total_bytes
    }

    pub fn total_messages(&self) -> u64 {
        self.total_messages
    }

    pub fn rounds(&self) -> u64 {
        self.rounds
    }

    /// Simulated wall time spent in the network so far.
    pub fn sim_elapsed_s(&self) -> f64 {
        self.sim_elapsed_s
    }

    pub fn party(&self, id: PartyId) -> TransferStats {
        self.per_party.get(&id).cloned().unwrap_or_default()
    }

    /// Re-price the recorded traffic under a different link without
    /// replaying the protocol (bandwidth sweeps in Fig. 5c/6b): time scales
    /// as `recorded_serialization · (bw_old/bw_new) + rounds · rtt_new`.
    pub fn reprice(&self, new_spec: LinkSpec) -> f64 {
        let serialization = self.sim_elapsed_s - self.rounds as f64 * self.spec.rtt_s;
        serialization * (self.spec.bandwidth_bps / new_spec.bandwidth_bps)
            + self.rounds as f64 * new_spec.rtt_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec_1gbps() -> LinkSpec {
        LinkSpec {
            bandwidth_bps: 1e9,
            rtt_s: 0.05,
        }
    }

    #[test]
    fn single_send_counts() {
        let mut net = NetSim::new(spec_1gbps());
        net.send(TA, CSP, 1000);
        assert_eq!(net.total_bytes(), 1000);
        assert_eq!(net.total_messages(), 1);
        assert_eq!(net.rounds(), 1);
        assert_eq!(net.party(TA).bytes_sent, 1000);
        assert_eq!(net.party(CSP).bytes_received, 1000);
        // 8000 bits / 1e9 bps + 0.05
        assert!((net.sim_elapsed_s() - (8e3 / 1e9 + 0.05)).abs() < 1e-12);
    }

    #[test]
    fn concurrent_round_takes_max() {
        let mut net = NetSim::new(spec_1gbps());
        net.begin_round();
        net.send(USER_BASE, CSP, 4000);
        net.send(USER_BASE + 1, CSP, 1000);
        net.end_round();
        assert_eq!(net.rounds(), 1);
        // slowest sender: 4000 bytes
        assert!((net.sim_elapsed_s() - (4000.0 * 8.0 / 1e9 + 0.05)).abs() < 1e-12);
    }

    #[test]
    fn sequential_sends_accumulate_rtt() {
        let mut net = NetSim::new(spec_1gbps());
        net.send(TA, CSP, 10);
        net.send(CSP, TA, 10);
        assert_eq!(net.rounds(), 2);
        assert!(net.sim_elapsed_s() > 0.1 - 1e-9); // 2 × 50 ms RTT dominates
    }

    #[test]
    fn same_sender_in_round_serializes() {
        let mut net = NetSim::new(spec_1gbps());
        net.begin_round();
        net.send(TA, USER_BASE, 1000);
        net.send(TA, USER_BASE + 1, 1000); // same sender → serialize
        net.end_round();
        assert!((net.sim_elapsed_s() - (2000.0 * 8.0 / 1e9 + 0.05)).abs() < 1e-12);
    }

    #[test]
    fn broadcast_meters_each_receiver() {
        let mut net = NetSim::new(spec_1gbps());
        net.broadcast(TA, &[USER_BASE, USER_BASE + 1, USER_BASE + 2], 500);
        assert_eq!(net.total_messages(), 3);
        assert_eq!(net.total_bytes(), 1500);
        assert_eq!(net.party(TA).bytes_sent, 1500);
        assert_eq!(net.rounds(), 1);
    }

    #[test]
    fn reprice_scales_serialization_and_latency() {
        let mut net = NetSim::new(spec_1gbps());
        net.send(TA, CSP, 125_000_000); // 1 Gb → 1 s serialization + 50 ms
        let t_orig = net.sim_elapsed_s();
        assert!((t_orig - 1.05).abs() < 1e-9);
        // half the bandwidth, double the latency
        let repriced = net.reprice(LinkSpec {
            bandwidth_bps: 0.5e9,
            rtt_s: 0.1,
        });
        assert!((repriced - (2.0 + 0.1)).abs() < 1e-9);
    }

    #[test]
    fn nested_rounds_merge_into_outer() {
        // two senders each bracket their own sends inside an outer round:
        // everything lands in ONE round and the slowest sender sets the time
        let mut net = NetSim::new(spec_1gbps());
        net.begin_round();
        net.begin_round(); // sender A's bracket
        net.send(USER_BASE, CSP, 4000);
        net.end_round();
        assert_eq!(net.round_depth(), 1, "outer round must still be open");
        assert_eq!(net.rounds(), 0, "inner end_round must not charge");
        net.begin_round(); // sender B's bracket
        net.send(USER_BASE + 1, CSP, 1000);
        net.end_round();
        net.end_round();
        assert_eq!(net.rounds(), 1);
        assert_eq!(net.round_depth(), 0);
        assert!((net.sim_elapsed_s() - (4000.0 * 8.0 / 1e9 + 0.05)).abs() < 1e-12);
    }

    #[test]
    fn overlapping_concurrent_senders_share_one_round() {
        // the cluster-runtime shape: threads interleave begin/send/end
        // brackets under a shared open round — accounting must stay the
        // concurrent-overlap model (max per sender), not serialize.
        use std::sync::{Arc, Barrier, Mutex};
        let net = Arc::new(Mutex::new(NetSim::new(spec_1gbps())));
        net.lock().unwrap().begin_round();
        let gate = Arc::new(Barrier::new(2));
        let handles: Vec<_> = (0..2)
            .map(|i| {
                let net = Arc::clone(&net);
                let gate = Arc::clone(&gate);
                std::thread::spawn(move || {
                    gate.wait();
                    let mut n = net.lock().unwrap();
                    n.begin_round();
                    n.send(USER_BASE + i, CSP, 2000 * (i as u64 + 1));
                    n.end_round();
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let mut n = net.lock().unwrap();
        n.end_round();
        assert_eq!(n.rounds(), 1);
        assert_eq!(n.total_messages(), 2);
        // slowest sender (4000 B) gates the round
        assert!((n.sim_elapsed_s() - (4000.0 * 8.0 / 1e9 + 0.05)).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "no open round")]
    fn unmatched_end_round_panics() {
        let mut net = NetSim::new(spec_1gbps());
        net.end_round();
    }
}
