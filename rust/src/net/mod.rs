//! Simulated federated network.
//!
//! The paper evaluates inside Docker containers with an emulated
//! bandwidth/latency bridge (Appendix A; Tab. 2 uses 1 Gb/s and RTT 50 ms).
//! We reproduce the same cost model in-process: every protocol message is
//! metered (bytes, sender, receiver), messages that happen concurrently
//! are grouped into *rounds*, and simulated network time is
//!
//! `elapsed = Σ_rounds ( max_bytes_in_round · 8 / bandwidth + RTT )`
//!
//! which is exactly the serialization + propagation model `tc`-shaped
//! links expose to an application that waits for the slowest peer in each
//! communication round. Fig. 5(b,c,d,f) and Fig. 6(b,c) read their
//! numbers from these meters.

pub mod link;

pub use link::{LinkSpec, NetSim, PartyId, TransferStats};

/// Standard link presets used across benches (paper defaults).
pub mod presets {
    use super::LinkSpec;

    /// Tab. 2 setting: 1 Gb/s, RTT 50 ms.
    pub fn paper_default() -> LinkSpec {
        LinkSpec {
            bandwidth_bps: 1e9,
            rtt_s: 0.050,
        }
    }

    /// LAN-ish: 10 Gb/s, RTT 1 ms.
    pub fn lan() -> LinkSpec {
        LinkSpec {
            bandwidth_bps: 10e9,
            rtt_s: 0.001,
        }
    }

    /// WAN-ish: 100 Mb/s, RTT 100 ms.
    pub fn wan() -> LinkSpec {
        LinkSpec {
            bandwidth_bps: 100e6,
            rtt_s: 0.100,
        }
    }
}
