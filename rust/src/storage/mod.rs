//! Disk offloading via data-access patterns (paper §3.4, Opt3).
//!
//! Large matrices (the paper's example: a 100K×1M f64 matrix ≈ 745 GB)
//! cannot stay resident; FedSVD offloads them to disk and streams blocks.
//! The paper's insight is that *naive OS swap is layout-oblivious*: a
//! row-major file read column-by-column touches every page per column.
//! FedSVD instead stores each file-backed matrix **adaptively in the
//! layout matching its access pattern** and streams blocks sequentially
//! (−44.7% time vs swap in §5.5).
//!
//! * [`filemap::FileMat`] — file-backed f64 matrix with an explicit
//!   [`filemap::Layout`]; reads/writes rows, columns and blocks with
//!   positioned I/O.
//! * [`offload::OffloadPolicy`] — `Advanced` (layout matches declared
//!   access pattern) vs `SwapLike` (always row-major + small-page strided
//!   reads, emulating what OS swap does to a column scan). The Fig. 7 /
//!   §5.5 ablation benches both.

pub mod filemap;
pub mod offload;

pub use filemap::{FileMat, Layout};
pub use offload::{OffloadPolicy, OffloadedMat};
