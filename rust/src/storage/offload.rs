//! Offloading policies: the paper's advanced strategy vs an OS-swap-like
//! baseline (§3.4 / §5.5 ablation).

use super::filemap::{FileMat, Layout};
use crate::linalg::Mat;
use crate::util::Result;
use std::path::Path;

/// How a large matrix is kept on disk and streamed back.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OffloadPolicy {
    /// Paper's Opt3: layout chosen to match the declared access pattern,
    /// blocks streamed sequentially in large reads.
    Advanced,
    /// OS-swap emulation: storage is always row-major regardless of the
    /// access pattern, and reads happen in page-size (512-element) strides
    /// the way faulting pages come in — layout-oblivious.
    SwapLike,
}

/// Declared dominant access pattern for an offloaded matrix.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AccessPattern {
    ByRowBlocks,
    ByColBlocks,
}

/// A matrix that lives on disk and is streamed block-by-block.
pub struct OffloadedMat {
    file: FileMat,
    policy: OffloadPolicy,
    pattern: AccessPattern,
}

impl OffloadedMat {
    /// Offload `mat` to `path` under `policy` for the declared `pattern`.
    pub fn offload(
        path: &Path,
        mat: &Mat,
        policy: OffloadPolicy,
        pattern: AccessPattern,
    ) -> Result<Self> {
        let layout = match (policy, pattern) {
            // Opt3: store adaptively — column access ⇒ col-major file
            (OffloadPolicy::Advanced, AccessPattern::ByColBlocks) => Layout::ColMajor,
            (OffloadPolicy::Advanced, AccessPattern::ByRowBlocks) => Layout::RowMajor,
            // swap never adapts
            (OffloadPolicy::SwapLike, _) => Layout::RowMajor,
        };
        let file = FileMat::from_mat(path, mat, layout)?;
        Ok(Self {
            file,
            policy,
            pattern,
        })
    }

    pub fn rows(&self) -> usize {
        self.file.rows()
    }
    pub fn cols(&self) -> usize {
        self.file.cols()
    }
    pub fn policy(&self) -> OffloadPolicy {
        self.policy
    }

    /// Stream the next block along the declared pattern.
    ///
    /// `index`/`width` are in units of the pattern axis (rows for
    /// ByRowBlocks, cols for ByColBlocks).
    pub fn read_block(&self, start: usize, width: usize) -> Result<Mat> {
        match self.pattern {
            AccessPattern::ByRowBlocks => {
                let end = (start + width).min(self.rows());
                match self.policy {
                    OffloadPolicy::Advanced => self.file.read_row_block(start, end),
                    OffloadPolicy::SwapLike => self.swaplike_row_block(start, end),
                }
            }
            AccessPattern::ByColBlocks => {
                let end = (start + width).min(self.cols());
                match self.policy {
                    OffloadPolicy::Advanced => self.file.read_col_block(start, end),
                    OffloadPolicy::SwapLike => self.swaplike_col_block(start, end),
                }
            }
        }
    }

    /// Number of blocks of `width` along the pattern axis.
    pub fn n_blocks(&self, width: usize) -> usize {
        let axis = match self.pattern {
            AccessPattern::ByRowBlocks => self.rows(),
            AccessPattern::ByColBlocks => self.cols(),
        };
        axis.div_ceil(width.max(1))
    }

    /// Swap emulation for row blocks: page-granular reads (rows arrive in
    /// 4 KiB faults rather than one large sequential read).
    fn swaplike_row_block(&self, r0: usize, r1: usize) -> Result<Mat> {
        const PAGE_ELEMS: usize = 512; // 4 KiB / 8
        let cols = self.cols();
        let mut out = Mat::zeros(r1 - r0, cols);
        for r in r0..r1 {
            let mut c = 0;
            while c < cols {
                let w = PAGE_ELEMS.min(cols - c);
                let page = self.file.read_col_block(c, c + w)?; // strided path
                for j in 0..w {
                    out[(r - r0, c + j)] = page[(r, j)];
                }
                c += w;
            }
        }
        Ok(out)
    }

    /// Swap emulation for column blocks: the file is row-major, so a
    /// column scan faults one page per (row, column-group) — exactly the
    /// "access by column conflicts with storage by row" case of §3.4.
    fn swaplike_col_block(&self, c0: usize, c1: usize) -> Result<Mat> {
        let rows = self.rows();
        let mut out = Mat::zeros(rows, c1 - c0);
        for c in c0..c1 {
            // element-at-a-time positioned reads = page-fault pattern
            for r in 0..rows {
                out[(r, c - c0)] = self.file.get(r, c)?;
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;
    use crate::util::max_abs_diff;
    use std::path::PathBuf;
    use std::time::Instant;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("fedsvd_offload_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn both_policies_read_identical_data() {
        let mut rng = Xoshiro256::seed_from_u64(1);
        let a = Mat::gaussian(20, 12, &mut rng);
        for pattern in [AccessPattern::ByRowBlocks, AccessPattern::ByColBlocks] {
            let adv =
                OffloadedMat::offload(&tmp("adv.bin"), &a, OffloadPolicy::Advanced, pattern)
                    .unwrap();
            let swp =
                OffloadedMat::offload(&tmp("swp.bin"), &a, OffloadPolicy::SwapLike, pattern)
                    .unwrap();
            let b1 = adv.read_block(3, 5).unwrap();
            let b2 = swp.read_block(3, 5).unwrap();
            assert!(max_abs_diff(b1.data(), b2.data()) == 0.0, "{pattern:?}");
        }
    }

    #[test]
    fn block_iteration_covers_matrix() {
        let a = Mat::from_fn(10, 6, |i, j| (i * 6 + j) as f64);
        let off = OffloadedMat::offload(
            &tmp("iter.bin"),
            &a,
            OffloadPolicy::Advanced,
            AccessPattern::ByRowBlocks,
        )
        .unwrap();
        assert_eq!(off.n_blocks(4), 3);
        let mut rebuilt = Mat::zeros(10, 6);
        for b in 0..off.n_blocks(4) {
            let blk = off.read_block(b * 4, 4).unwrap();
            rebuilt.set_slice(b * 4, 0, &blk);
        }
        assert!(max_abs_diff(rebuilt.data(), a.data()) == 0.0);
    }

    #[test]
    fn ragged_tail_block() {
        let a = Mat::from_fn(7, 3, |i, j| (i + j) as f64);
        let off = OffloadedMat::offload(
            &tmp("rag.bin"),
            &a,
            OffloadPolicy::Advanced,
            AccessPattern::ByRowBlocks,
        )
        .unwrap();
        let tail = off.read_block(4, 4).unwrap(); // only 3 rows remain
        assert_eq!(tail.shape(), (3, 3));
        assert_eq!(tail[(2, 2)], 8.0);
    }

    #[test]
    fn advanced_faster_than_swaplike_on_col_scan() {
        // the §5.5 claim in miniature: column-block streaming from a
        // layout-matched file beats the swap-like strided read.
        let mut rng = Xoshiro256::seed_from_u64(2);
        let a = Mat::gaussian(256, 256, &mut rng);

        let adv = OffloadedMat::offload(
            &tmp("perf_adv.bin"),
            &a,
            OffloadPolicy::Advanced,
            AccessPattern::ByColBlocks,
        )
        .unwrap();
        let swp = OffloadedMat::offload(
            &tmp("perf_swp.bin"),
            &a,
            OffloadPolicy::SwapLike,
            AccessPattern::ByColBlocks,
        )
        .unwrap();

        let t0 = Instant::now();
        for b in 0..adv.n_blocks(64) {
            adv.read_block(b * 64, 64).unwrap();
        }
        let t_adv = t0.elapsed();

        let t0 = Instant::now();
        for b in 0..swp.n_blocks(64) {
            swp.read_block(b * 64, 64).unwrap();
        }
        let t_swp = t0.elapsed();

        assert!(
            t_adv < t_swp,
            "advanced {t_adv:?} should beat swap-like {t_swp:?}"
        );
    }
}
