//! File-backed f64 matrices with explicit storage layout.

use crate::linalg::Mat;
use crate::util::{Error, Result};
use std::fs::{File, OpenOptions};
use std::os::unix::fs::FileExt;
use std::path::{Path, PathBuf};

/// On-disk element order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Layout {
    /// Elements of a row are contiguous (fast row/row-block access).
    RowMajor,
    /// Elements of a column are contiguous (fast column/col-block access).
    ColMajor,
}

/// A dense f64 matrix stored in a file ("file map" in the paper's words),
/// with a small in-memory header only.
pub struct FileMat {
    file: File,
    path: PathBuf,
    rows: usize,
    cols: usize,
    layout: Layout,
}

impl FileMat {
    /// Create (truncate) a file-backed matrix of zeros.
    pub fn create(path: &Path, rows: usize, cols: usize, layout: Layout) -> Result<Self> {
        let file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(true)
            .open(path)?;
        file.set_len((rows * cols * 8) as u64)?;
        Ok(Self {
            file,
            path: path.to_path_buf(),
            rows,
            cols,
            layout,
        })
    }

    /// Write an in-memory matrix out in the given layout.
    pub fn from_mat(path: &Path, mat: &Mat, layout: Layout) -> Result<Self> {
        let fm = Self::create(path, mat.rows(), mat.cols(), layout)?;
        match layout {
            Layout::RowMajor => {
                // Mat is row-major: single bulk write
                fm.write_elems(0, mat.data())?;
            }
            Layout::ColMajor => {
                let t = mat.transpose();
                fm.write_elems(0, t.data())?;
            }
        }
        Ok(fm)
    }

    pub fn rows(&self) -> usize {
        self.rows
    }
    pub fn cols(&self) -> usize {
        self.cols
    }
    pub fn layout(&self) -> Layout {
        self.layout
    }
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// File size in bytes.
    pub fn byte_len(&self) -> u64 {
        (self.rows * self.cols * 8) as u64
    }

    #[inline]
    fn offset_of(&self, r: usize, c: usize) -> u64 {
        let idx = match self.layout {
            Layout::RowMajor => r * self.cols + c,
            Layout::ColMajor => c * self.rows + r,
        };
        (idx * 8) as u64
    }

    fn write_elems(&self, elem_offset: usize, vals: &[f64]) -> Result<()> {
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        self.file.write_all_at(&bytes, (elem_offset * 8) as u64)?;
        Ok(())
    }

    fn read_elems(&self, elem_offset: usize, count: usize) -> Result<Vec<f64>> {
        let mut buf = vec![0u8; count * 8];
        self.file.read_exact_at(&mut buf, (elem_offset * 8) as u64)?;
        Ok(buf
            .chunks_exact(8)
            .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    /// Read a single element (random access; header arithmetic only).
    pub fn get(&self, r: usize, c: usize) -> Result<f64> {
        if r >= self.rows || c >= self.cols {
            return Err(Error::Shape(format!(
                "FileMat::get ({r},{c}) out of {}x{}",
                self.rows, self.cols
            )));
        }
        let mut buf = [0u8; 8];
        self.file.read_exact_at(&mut buf, self.offset_of(r, c))?;
        Ok(f64::from_le_bytes(buf))
    }

    /// Read one full row. Contiguous when layout is RowMajor, strided
    /// (one positioned read per element) otherwise — the cost asymmetry
    /// the Opt3 ablation measures.
    pub fn read_row(&self, r: usize) -> Result<Vec<f64>> {
        if r >= self.rows {
            return Err(Error::Shape("read_row: row out of range".into()));
        }
        match self.layout {
            Layout::RowMajor => self.read_elems(r * self.cols, self.cols),
            Layout::ColMajor => {
                let mut out = Vec::with_capacity(self.cols);
                for c in 0..self.cols {
                    out.push(self.get(r, c)?);
                }
                Ok(out)
            }
        }
    }

    /// Read one full column (mirror of `read_row`).
    pub fn read_col(&self, c: usize) -> Result<Vec<f64>> {
        if c >= self.cols {
            return Err(Error::Shape("read_col: col out of range".into()));
        }
        match self.layout {
            Layout::ColMajor => self.read_elems(c * self.rows, self.rows),
            Layout::RowMajor => {
                let mut out = Vec::with_capacity(self.rows);
                for r in 0..self.rows {
                    out.push(self.get(r, c)?);
                }
                Ok(out)
            }
        }
    }

    /// Read rows [r0, r1) as a Mat.
    pub fn read_row_block(&self, r0: usize, r1: usize) -> Result<Mat> {
        if r1 > self.rows || r0 > r1 {
            return Err(Error::Shape("read_row_block: range".into()));
        }
        match self.layout {
            Layout::RowMajor => {
                let data = self.read_elems(r0 * self.cols, (r1 - r0) * self.cols)?;
                Mat::from_vec(r1 - r0, self.cols, data)
            }
            Layout::ColMajor => {
                let mut out = Mat::zeros(r1 - r0, self.cols);
                for c in 0..self.cols {
                    let col = self.read_elems(c * self.rows + r0, r1 - r0)?;
                    for (i, v) in col.into_iter().enumerate() {
                        out[(i, c)] = v;
                    }
                }
                Ok(out)
            }
        }
    }

    /// Read columns [c0, c1) as a Mat.
    pub fn read_col_block(&self, c0: usize, c1: usize) -> Result<Mat> {
        if c1 > self.cols || c0 > c1 {
            return Err(Error::Shape("read_col_block: range".into()));
        }
        match self.layout {
            Layout::ColMajor => {
                let data = self.read_elems(c0 * self.rows, (c1 - c0) * self.rows)?;
                // data is col-major: transpose into Mat
                let t = Mat::from_vec(c1 - c0, self.rows, data)?;
                Ok(t.transpose())
            }
            Layout::RowMajor => {
                let mut out = Mat::zeros(self.rows, c1 - c0);
                for r in 0..self.rows {
                    let row = self.read_elems(r * self.cols + c0, c1 - c0)?;
                    out.row_mut(r).copy_from_slice(&row);
                }
                Ok(out)
            }
        }
    }

    /// Overwrite rows [r0, r0+block.rows).
    pub fn write_row_block(&self, r0: usize, block: &Mat) -> Result<()> {
        if block.cols() != self.cols || r0 + block.rows() > self.rows {
            return Err(Error::Shape("write_row_block: shape".into()));
        }
        match self.layout {
            Layout::RowMajor => self.write_elems(r0 * self.cols, block.data()),
            Layout::ColMajor => {
                for c in 0..self.cols {
                    let col: Vec<f64> = (0..block.rows()).map(|r| block[(r, c)]).collect();
                    self.write_elems(c * self.rows + r0, &col)?;
                }
                Ok(())
            }
        }
    }

    /// Load the whole matrix (tests / small matrices).
    pub fn to_mat(&self) -> Result<Mat> {
        self.read_row_block(0, self.rows)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;
    use crate::util::max_abs_diff;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("fedsvd_filemap_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn roundtrip_row_major() {
        let mut rng = Xoshiro256::seed_from_u64(1);
        let a = Mat::gaussian(7, 5, &mut rng);
        let fm = FileMat::from_mat(&tmp("rm.bin"), &a, Layout::RowMajor).unwrap();
        let b = fm.to_mat().unwrap();
        assert!(max_abs_diff(a.data(), b.data()) == 0.0);
    }

    #[test]
    fn roundtrip_col_major() {
        let mut rng = Xoshiro256::seed_from_u64(2);
        let a = Mat::gaussian(6, 9, &mut rng);
        let fm = FileMat::from_mat(&tmp("cm.bin"), &a, Layout::ColMajor).unwrap();
        let b = fm.to_mat().unwrap();
        assert!(max_abs_diff(a.data(), b.data()) == 0.0);
    }

    #[test]
    fn row_and_col_reads_match_memory() {
        let mut rng = Xoshiro256::seed_from_u64(3);
        let a = Mat::gaussian(8, 4, &mut rng);
        for layout in [Layout::RowMajor, Layout::ColMajor] {
            let fm = FileMat::from_mat(&tmp("rc.bin"), &a, layout).unwrap();
            for r in 0..8 {
                assert_eq!(fm.read_row(r).unwrap(), a.row(r).to_vec(), "{layout:?}");
            }
            for c in 0..4 {
                assert_eq!(fm.read_col(c).unwrap(), a.col(c), "{layout:?}");
            }
        }
    }

    #[test]
    fn block_reads() {
        let a = Mat::from_fn(6, 6, |i, j| (i * 10 + j) as f64);
        for layout in [Layout::RowMajor, Layout::ColMajor] {
            let fm = FileMat::from_mat(&tmp("blk.bin"), &a, layout).unwrap();
            let rb = fm.read_row_block(2, 5).unwrap();
            assert_eq!(rb.shape(), (3, 6));
            assert_eq!(rb[(0, 0)], 20.0);
            assert_eq!(rb[(2, 5)], 45.0);
            let cb = fm.read_col_block(1, 3).unwrap();
            assert_eq!(cb.shape(), (6, 2));
            assert_eq!(cb[(0, 0)], 1.0);
            assert_eq!(cb[(5, 1)], 52.0);
        }
    }

    #[test]
    fn write_row_block_updates() {
        let a = Mat::zeros(4, 3);
        let fm = FileMat::from_mat(&tmp("wr.bin"), &a, Layout::RowMajor).unwrap();
        let block = Mat::from_fn(2, 3, |i, j| (i + j) as f64 + 1.0);
        fm.write_row_block(1, &block).unwrap();
        let m = fm.to_mat().unwrap();
        assert_eq!(m[(0, 0)], 0.0);
        assert_eq!(m[(1, 0)], 1.0);
        assert_eq!(m[(2, 2)], 4.0);
        // also correct under ColMajor
        let fm2 = FileMat::from_mat(&tmp("wr2.bin"), &a, Layout::ColMajor).unwrap();
        fm2.write_row_block(1, &block).unwrap();
        let m2 = fm2.to_mat().unwrap();
        assert!(max_abs_diff(m.data(), m2.data()) == 0.0);
    }

    #[test]
    fn col_block_roundtrip_ragged_widths_both_layouts() {
        // 7×5 with block widths that never divide the axis: the shard
        // spill path reads exactly these ragged tails
        let a = Mat::from_fn(7, 5, |i, j| (i * 100 + j) as f64);
        for layout in [Layout::RowMajor, Layout::ColMajor] {
            let fm = FileMat::from_mat(&tmp("ragc.bin"), &a, layout).unwrap();
            for width in [2usize, 3, 4] {
                let mut c0 = 0usize;
                let mut rebuilt = Mat::zeros(7, 5);
                while c0 < 5 {
                    let c1 = (c0 + width).min(5);
                    let blk = fm.read_col_block(c0, c1).unwrap();
                    assert_eq!(blk.shape(), (7, c1 - c0), "{layout:?} w={width}");
                    rebuilt.set_slice(0, c0, &blk);
                    c0 = c1;
                }
                assert!(
                    max_abs_diff(rebuilt.data(), a.data()) == 0.0,
                    "{layout:?} width {width}"
                );
            }
            // empty block at the very end is legal and zero-sized
            let empty = fm.read_col_block(5, 5).unwrap();
            assert_eq!(empty.shape(), (7, 0));
        }
    }

    #[test]
    fn write_row_block_roundtrip_ragged_heights_both_layouts() {
        let mut rng = Xoshiro256::seed_from_u64(9);
        let a = Mat::gaussian(11, 4, &mut rng);
        for layout in [Layout::RowMajor, Layout::ColMajor] {
            let zero = Mat::zeros(11, 4);
            let fm = FileMat::from_mat(&tmp("ragw.bin"), &zero, layout).unwrap();
            // write back in ragged row blocks (11 = 4 + 4 + 3)
            let mut r0 = 0usize;
            while r0 < 11 {
                let r1 = (r0 + 4).min(11);
                fm.write_row_block(r0, &a.slice(r0, r1, 0, 4)).unwrap();
                r0 = r1;
            }
            // read back through BOTH access paths
            let whole = fm.to_mat().unwrap();
            assert!(max_abs_diff(whole.data(), a.data()) == 0.0, "{layout:?}");
            let tail = fm.read_row_block(8, 11).unwrap();
            assert!(max_abs_diff(tail.data(), a.slice(8, 11, 0, 4).data()) == 0.0);
            let cols = fm.read_col_block(1, 4).unwrap();
            assert!(max_abs_diff(cols.data(), a.slice(0, 11, 1, 4).data()) == 0.0);
        }
    }

    #[test]
    fn bounds_errors() {
        let a = Mat::zeros(3, 3);
        let fm = FileMat::from_mat(&tmp("be.bin"), &a, Layout::RowMajor).unwrap();
        assert!(fm.get(3, 0).is_err());
        assert!(fm.read_row(5).is_err());
        assert!(fm.read_col_block(2, 5).is_err());
    }
}
