//! Minimal benchmark harness (criterion is not in the offline vendor set).
//!
//! Each `rust/benches/*.rs` binary (`harness = false`) uses [`Bench`] to
//! time closures with warmup + repetitions and print median/min, plus the
//! table-row printers shared by the per-figure reproduction benches.

use std::time::Instant;

/// Timing result for one benchmark case.
#[derive(Debug, Clone)]
pub struct Sample {
    pub name: String,
    pub median_s: f64,
    pub min_s: f64,
    pub max_s: f64,
    pub reps: usize,
}

/// Run `f` `reps` times after `warmup` unrecorded runs; report stats.
pub fn bench<T>(name: &str, warmup: usize, reps: usize, mut f: impl FnMut() -> T) -> Sample {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let reps = reps.max(1);
    let mut times = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        std::hint::black_box(f());
        times.push(t0.elapsed().as_secs_f64());
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Sample {
        name: name.to_string(),
        median_s: times[times.len() / 2],
        min_s: times[0],
        max_s: *times.last().unwrap(),
        reps,
    }
}

impl Sample {
    pub fn row(&self) -> String {
        format!(
            "{:<40} median {:>12}  min {:>12}  (n={})",
            self.name,
            crate::util::human_secs(self.median_s),
            crate::util::human_secs(self.min_s),
            self.reps
        )
    }
}

/// Print a bench-section header (figure/table id + caption).
pub fn section(id: &str, caption: &str) {
    println!("\n=== {id}: {caption} ===");
}

/// Print one row of a paper-style results table.
pub fn row(cells: &[String]) {
    println!("{}", cells.join(" | "));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_returns_ordered_stats() {
        let s = bench("noop", 1, 5, || 1 + 1);
        assert!(s.min_s <= s.median_s && s.median_s <= s.max_s);
        assert_eq!(s.reps, 5);
        assert!(s.row().contains("noop"));
    }

    #[test]
    fn bench_measures_work() {
        let fast = bench("fast", 0, 3, || (0..10u64).sum::<u64>());
        let slow = bench("slow", 0, 3, || {
            let mut acc = 0f64;
            for i in 0..200_000u64 {
                acc += (i as f64).sqrt();
            }
            acc
        });
        assert!(slow.median_s > fast.median_s);
    }
}
