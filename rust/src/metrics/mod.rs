//! Phase timing, memory gauges and experiment reporting.
//!
//! Every experiment in EXPERIMENTS.md is produced through a
//! [`MetricsRecorder`]: named phases with wall time, simulated network
//! time folded in from [`crate::net::NetSim`], a peak-memory gauge (both
//! an in-process logical gauge and the kernel's VmHWM), and a tabular
//! printer shared by benches.

pub mod jsonl;
pub mod trajectory;

use std::time::Instant;

/// One completed phase.
#[derive(Debug, Clone)]
pub struct Phase {
    pub name: String,
    pub wall_s: f64,
    pub net_s: f64,
    pub bytes: u64,
}

/// Records phases of one experiment run.
#[derive(Debug, Default)]
pub struct MetricsRecorder {
    phases: Vec<Phase>,
    open: Option<(String, Instant, f64, u64)>,
    /// logical bytes currently "resident" as declared by the caller
    mem_gauge: u64,
    mem_peak: u64,
}

impl MetricsRecorder {
    pub fn new() -> Self {
        Self::default()
    }

    /// Begin a named phase; `net_baseline`/`bytes_baseline` are the network
    /// meters at phase start (pass the live values from NetSim).
    pub fn begin(&mut self, name: &str, net_baseline_s: f64, bytes_baseline: u64) {
        assert!(self.open.is_none(), "phase {name}: previous phase still open");
        // Phases double as trace spans when the calling thread runs a
        // party (no-op otherwise — benches use recorders standalone).
        crate::obs::with_current(|t| t.span_enter(name, None));
        self.open = Some((name.to_string(), Instant::now(), net_baseline_s, bytes_baseline));
    }

    /// End the open phase with the network meters at phase end.
    pub fn end(&mut self, net_now_s: f64, bytes_now: u64) {
        let (name, start, net0, bytes0) = self.open.take().expect("no open phase");
        let phase = Phase {
            name,
            wall_s: start.elapsed().as_secs_f64(),
            net_s: net_now_s - net0,
            bytes: bytes_now - bytes0,
        };
        crate::obs::with_current(|t| {
            t.span_leave(&phase.name, None, Some(phase.bytes));
            // Phase boundaries are the "periodic" cadence for the
            // process-global hot-path counters.
            t.counter_snapshot();
        });
        crate::obs::metrics_live::on_phase((phase.wall_s * 1e6) as u64);
        self.phases.push(phase);
    }

    /// Convenience for phases with no network activity.
    pub fn time<T>(&mut self, name: &str, f: impl FnOnce() -> T) -> T {
        self.begin(name, 0.0, 0);
        let out = f();
        self.end(0.0, 0);
        out
    }

    pub fn phases(&self) -> &[Phase] {
        &self.phases
    }

    pub fn total_wall_s(&self) -> f64 {
        self.phases.iter().map(|p| p.wall_s).sum()
    }

    pub fn total_net_s(&self) -> f64 {
        self.phases.iter().map(|p| p.net_s).sum()
    }

    pub fn total_bytes(&self) -> u64 {
        self.phases.iter().map(|p| p.bytes).sum()
    }

    /// Wall + simulated network = the end-to-end figure the paper reports.
    pub fn total_elapsed_s(&self) -> f64 {
        self.total_wall_s() + self.total_net_s()
    }

    /// Declare `bytes` allocated in the logical memory gauge.
    pub fn mem_alloc(&mut self, bytes: u64) {
        self.mem_gauge += bytes;
        self.mem_peak = self.mem_peak.max(self.mem_gauge);
    }

    /// Declare `bytes` released.
    pub fn mem_free(&mut self, bytes: u64) {
        self.mem_gauge = self.mem_gauge.saturating_sub(bytes);
    }

    /// Peak of the logical gauge.
    pub fn mem_peak(&self) -> u64 {
        self.mem_peak
    }

    /// Fold another recorder's phases into this one, each renamed to
    /// `prefix/name`. Used by the cluster runtime to merge the per-party
    /// recorders (TA, CSP, user-i run on their own threads) into one
    /// report whose rows stay attributable to a party. The memory peak
    /// takes the max — parties are concurrent, but each gauge tracks a
    /// different process-role's resident set, so max is the honest bound
    /// per party (sums would double-count simulated machines).
    pub fn absorb_prefixed(&mut self, prefix: &str, other: &MetricsRecorder) {
        assert!(other.open.is_none(), "absorb_prefixed: donor has open phase");
        for p in &other.phases {
            self.phases.push(Phase {
                name: format!("{prefix}/{}", p.name),
                ..p.clone()
            });
        }
        self.mem_peak = self.mem_peak.max(other.mem_peak);
    }

    /// Render a fixed-width table of phases for experiment logs.
    pub fn table(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "{:<28} {:>12} {:>12} {:>14}\n",
            "phase", "wall", "network", "bytes"
        ));
        for p in &self.phases {
            out.push_str(&format!(
                "{:<28} {:>12} {:>12} {:>14}\n",
                p.name,
                crate::util::human_secs(p.wall_s),
                crate::util::human_secs(p.net_s),
                crate::util::human_bytes(p.bytes)
            ));
        }
        out.push_str(&format!(
            "{:<28} {:>12} {:>12} {:>14}\n",
            "TOTAL",
            crate::util::human_secs(self.total_wall_s()),
            crate::util::human_secs(self.total_net_s()),
            crate::util::human_bytes(self.total_bytes())
        ));
        out
    }
}

/// Kernel-reported peak RSS of this process (VmHWM), in bytes.
/// Returns 0 when /proc is unavailable.
pub fn process_peak_rss_bytes() -> u64 {
    let Ok(status) = std::fs::read_to_string("/proc/self/status") else {
        return 0;
    };
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            let kb: u64 = rest
                .trim()
                .trim_end_matches("kB")
                .trim()
                .parse()
                .unwrap_or(0);
            return kb * 1024;
        }
    }
    0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phases_accumulate() {
        let mut m = MetricsRecorder::new();
        m.begin("a", 0.0, 0);
        std::thread::sleep(std::time::Duration::from_millis(5));
        m.end(0.5, 100);
        m.begin("b", 0.5, 100);
        m.end(0.75, 300);
        assert_eq!(m.phases().len(), 2);
        assert!(m.phases()[0].wall_s >= 0.004);
        assert!((m.phases()[0].net_s - 0.5).abs() < 1e-12);
        assert_eq!(m.phases()[1].bytes, 200);
        assert!((m.total_net_s() - 0.75).abs() < 1e-12);
        assert_eq!(m.total_bytes(), 300);
    }

    #[test]
    fn time_helper_returns_value() {
        let mut m = MetricsRecorder::new();
        let v = m.time("compute", || 41 + 1);
        assert_eq!(v, 42);
        assert_eq!(m.phases().len(), 1);
    }

    #[test]
    fn memory_gauge_tracks_peak() {
        let mut m = MetricsRecorder::new();
        m.mem_alloc(100);
        m.mem_alloc(250);
        m.mem_free(300);
        m.mem_alloc(10);
        assert_eq!(m.mem_peak(), 350);
    }

    #[test]
    #[should_panic(expected = "previous phase still open")]
    fn double_begin_panics() {
        let mut m = MetricsRecorder::new();
        m.begin("a", 0.0, 0);
        m.begin("b", 0.0, 0);
    }

    #[test]
    fn peak_rss_readable_on_linux() {
        let rss = process_peak_rss_bytes();
        // Only Linux guarantees /proc; elsewhere the gauge reads 0 by
        // contract and the assertion would be a false failure.
        #[cfg(target_os = "linux")]
        assert!(rss > 0, "VmHWM should be readable in CI");
        #[cfg(not(target_os = "linux"))]
        let _ = rss;
    }

    #[test]
    fn absorb_prefixed_renames_and_merges() {
        let mut a = MetricsRecorder::new();
        a.time("ingest", || ());
        a.mem_alloc(100);
        let mut b = MetricsRecorder::new();
        b.time("mask", || ());
        b.mem_alloc(300);
        b.mem_free(300);
        let mut merged = MetricsRecorder::new();
        merged.absorb_prefixed("csp", &a);
        merged.absorb_prefixed("user0", &b);
        let names: Vec<&str> = merged.phases().iter().map(|p| p.name.as_str()).collect();
        assert_eq!(names, vec!["csp/ingest", "user0/mask"]);
        assert_eq!(merged.mem_peak(), 300);
    }

    #[test]
    fn table_renders() {
        let mut m = MetricsRecorder::new();
        m.time("phase-x", || ());
        let t = m.table();
        assert!(t.contains("phase-x"));
        assert!(t.contains("TOTAL"));
    }
}
