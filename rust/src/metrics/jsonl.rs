//! Shared JSONL machinery: an escaping row builder and a minimal parser.
//!
//! Every JSON line this crate emits — bench rows, `obs` trace events, the
//! merged Chrome timeline — goes through [`JsonRow`], so escaping and
//! number formatting live in exactly one place (hand-rolled `format!`
//! rows can silently produce invalid JSON the moment a string field grows
//! a quote or a float goes non-finite). The matching [`Json`] parser is
//! deliberately tiny — recursive descent over the full JSON grammar — and
//! exists so `fedsvd trace merge` and the test suites can *read back*
//! what we emit without any external dependency.

use crate::util::{Error, Result};

/// Escape `s` as the body of a JSON string (no surrounding quotes).
pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Builder for one single-line JSON object (a JSONL row).
///
/// Field order is insertion order; floats are emitted with an explicit
/// precision (matching the bench-row conventions) and non-finite values
/// become `null` — JSON has no NaN/inf.
#[derive(Debug)]
pub struct JsonRow {
    buf: String,
}

impl Default for JsonRow {
    fn default() -> Self {
        Self::new()
    }
}

impl JsonRow {
    pub fn new() -> Self {
        JsonRow { buf: String::from("{") }
    }

    fn key(&mut self, k: &str) {
        if self.buf.len() > 1 {
            self.buf.push(',');
        }
        self.buf.push('"');
        self.buf.push_str(&escape(k));
        self.buf.push_str("\":");
    }

    pub fn str(mut self, k: &str, v: &str) -> Self {
        self.key(k);
        self.buf.push('"');
        self.buf.push_str(&escape(v));
        self.buf.push('"');
        self
    }

    pub fn u64(mut self, k: &str, v: u64) -> Self {
        self.key(k);
        self.buf.push_str(&v.to_string());
        self
    }

    pub fn bool(mut self, k: &str, v: bool) -> Self {
        self.key(k);
        self.buf.push_str(if v { "true" } else { "false" });
        self
    }

    /// Fixed-precision float, e.g. `f64("wall_s", 1.5, 6)` → `1.500000`.
    pub fn f64(mut self, k: &str, v: f64, prec: usize) -> Self {
        self.key(k);
        if v.is_finite() {
            self.buf.push_str(&format!("{v:.prec$}"));
        } else {
            self.buf.push_str("null");
        }
        self
    }

    /// Scientific-notation float, e.g. `f64e("mse", 1.5e-9, 6)` → `1.500000e-9`.
    pub fn f64e(mut self, k: &str, v: f64, prec: usize) -> Self {
        self.key(k);
        if v.is_finite() {
            self.buf.push_str(&format!("{v:.prec$e}"));
        } else {
            self.buf.push_str("null");
        }
        self
    }

    /// Pre-rendered JSON value (caller guarantees validity).
    pub fn raw(mut self, k: &str, json: &str) -> Self {
        self.key(k);
        self.buf.push_str(json);
        self
    }

    pub fn finish(mut self) -> String {
        self.buf.push('}');
        self.buf
    }
}

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Parse one complete JSON document (trailing whitespace allowed).
    pub fn parse(s: &str) -> Result<Json> {
        let b = s.as_bytes();
        let mut p = Parser { b, i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != b.len() {
            return Err(Error::Runtime(format!(
                "json: trailing garbage at byte {}",
                p.i
            )));
        }
        Ok(v)
    }

    /// Object field lookup (None for non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            // u64::MAX and friends round-trip through f64 lossily; accept
            // anything that is a non-negative integer once rounded.
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as u64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn err(&self, what: &str) -> Error {
        Error::Runtime(format!("json: {what} at byte {}", self.i))
    }

    fn value(&mut self) -> Result<Json> {
        match self.b.get(self.i) {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c.is_ascii_digit() || *c == b'-' => self.number(),
            _ => Err(self.err("expected value")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.b.get(self.i) == Some(&b'-') {
            self.i += 1;
        }
        while self
            .b
            .get(self.i)
            .is_some_and(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).expect("ascii slice");
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String> {
        if self.b.get(self.i) != Some(&b'"') {
            return Err(self.err("expected string"));
        }
        self.i += 1;
        let mut out = String::new();
        loop {
            match self.b.get(self.i) {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.b.get(self.i) {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let cp = self.hex4()?;
                            // Surrogate pair handling: a high surrogate must
                            // be followed by \uXXXX low surrogate.
                            if (0xD800..0xDC00).contains(&cp) {
                                if self.b.get(self.i + 1) == Some(&b'\\')
                                    && self.b.get(self.i + 2) == Some(&b'u')
                                {
                                    self.i += 2;
                                    let lo = self.hex4()?;
                                    let c = 0x10000
                                        + ((cp - 0xD800) << 10)
                                        + (lo.wrapping_sub(0xDC00) & 0x3FF);
                                    out.push(
                                        char::from_u32(c).ok_or_else(|| self.err("bad surrogate"))?,
                                    );
                                } else {
                                    return Err(self.err("lone surrogate"));
                                }
                            } else {
                                out.push(
                                    char::from_u32(cp)
                                        .ok_or_else(|| self.err("bad \\u escape"))?,
                                );
                            }
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar (input is a &str, so valid).
                    let rest = std::str::from_utf8(&self.b[self.i..]).expect("valid utf8");
                    let c = rest.chars().next().expect("non-empty");
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    /// Reads the 4 hex digits after `\u` (cursor on the `u`); leaves the
    /// cursor on the last digit.
    fn hex4(&mut self) -> Result<u32> {
        let mut v = 0u32;
        for k in 1..=4 {
            let d = self
                .b
                .get(self.i + k)
                .and_then(|c| (*c as char).to_digit(16))
                .ok_or_else(|| self.err("bad \\u escape"))?;
            v = v * 16 + d;
        }
        self.i += 4;
        Ok(v)
    }

    fn object(&mut self) -> Result<Json> {
        self.i += 1; // '{'
        let mut fields = Vec::new();
        self.ws();
        if self.b.get(self.i) == Some(&b'}') {
            self.i += 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            if self.b.get(self.i) != Some(&b':') {
                return Err(self.err("expected ':'"));
            }
            self.i += 1;
            self.ws();
            let v = self.value()?;
            fields.push((k, v));
            self.ws();
            match self.b.get(self.i) {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(fields));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.i += 1; // '['
        let mut items = Vec::new();
        self.ws();
        if self.b.get(self.i) == Some(&b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.b.get(self.i) {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn row_builder_emits_valid_single_line_json() {
        let row = JsonRow::new()
            .str("bench", "x\"y\\z\n")
            .u64("n", 42)
            .f64("wall_s", 1.5, 6)
            .f64e("mse", 0.00015, 3)
            .bool("ok", true)
            .f64("bad", f64::NAN, 3)
            .finish();
        assert!(!row.contains('\n') || row.contains("\\n"));
        assert!(row.starts_with('{') && row.ends_with('}'));
        let v = Json::parse(&row).unwrap();
        assert_eq!(v.get("bench").unwrap().as_str().unwrap(), "x\"y\\z\n");
        assert_eq!(v.get("n").unwrap().as_u64(), Some(42));
        assert!((v.get("wall_s").unwrap().as_f64().unwrap() - 1.5).abs() < 1e-12);
        assert!((v.get("mse").unwrap().as_f64().unwrap() - 1.5e-4).abs() < 1e-12);
        assert_eq!(v.get("ok").unwrap(), &Json::Bool(true));
        assert_eq!(v.get("bad").unwrap(), &Json::Null);
    }

    #[test]
    fn parser_round_trips_nested_values() {
        let src = r#"{"a":[1,2.5,-3e2],"b":{"c":null,"d":"A😀"},"e":[]}"#;
        let v = Json::parse(src).unwrap();
        let a = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a.len(), 3);
        assert!((a[2].as_f64().unwrap() + 300.0).abs() < 1e-12);
        assert_eq!(
            v.get("b").unwrap().get("d").unwrap().as_str().unwrap(),
            "A\u{1F600}"
        );
        assert_eq!(v.get("e").unwrap().as_arr().unwrap().len(), 0);
    }

    #[test]
    fn parser_rejects_malformed_documents() {
        for bad in [
            "",
            "{",
            "{\"a\":}",
            "[1,]",
            "{\"a\":1} extra",
            "\"unterminated",
            "{\"a\" 1}",
            "nul",
        ] {
            assert!(Json::parse(bad).is_err(), "accepted: {bad:?}");
        }
    }

    #[test]
    fn escape_controls_and_quotes() {
        assert_eq!(escape("a\"b"), "a\\\"b");
        assert_eq!(escape("a\u{1}b"), "a\\u0001b");
        assert_eq!(escape("tab\there"), "tab\\there");
    }
}
