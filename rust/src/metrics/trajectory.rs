//! Bench trajectory: noise-aware diffing of `bench_rows.jsonl` runs.
//!
//! Every bench in this repo emits machine-readable JSON rows (one
//! object per line, `bench`-discriminated). CI collects them into
//! `bench_rows.jsonl` per run — and, until this module, never compared
//! two runs, so the ROADMAP's "track the bench trajectory across PRs"
//! had no teeth. `fedsvd bench diff <old.jsonl> <new.jsonl>` closes the
//! loop:
//!
//! * rows are matched across runs by their **identity**: the `bench`
//!   name plus every configuration field ([`IDENTITY_KEYS`] — shape,
//!   ISA, thread count, transport, …). Measurement fields and unknown
//!   fields never participate in identity, so adding a metric to a
//!   bench does not orphan its history;
//! * each known metric ([`METRICS`]) carries a direction
//!   (lower-is-better or higher-is-better) and a per-metric **noise
//!   allowance** — wall-clock medians on shared CI runners jitter far
//!   more than byte counts, and the thresholds encode exactly that;
//! * beyond the soft per-metric regressions, a small set of **hard
//!   rules** ([`hard_regressions`]) guards the paper's headline scaling
//!   claims: the Step-2 4-thread speedup staying ≥ 2×, the GEMM
//!   micro-kernel's SIMD-vs-scalar advantage not collapsing, and
//!   bit-identical multi-thread masking staying bit-identical. A hard
//!   hit fails CI ([`DiffReport::has_hard_regressions`]); soft drifts
//!   and vocabulary changes (missing/new rows) are reported but pass.
//!
//! `BENCH_BASELINE.jsonl` at the repo root is the checked-in reference
//! run; re-seed it deliberately when a PR legitimately moves a
//! threshold (the report prints the exact rows to copy).

use crate::metrics::jsonl::{Json, JsonRow};
use crate::util::{Error, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// Fields that define a row's identity (when present). Everything else
/// on a row is either a known metric or ignored — varying integers like
/// `peak_rss` must never become identity, or no row would ever match.
pub const IDENTITY_KEYS: &[&str] = &[
    "bench",
    "shape",
    "isa",
    "mode",
    "transport",
    "format",
    "exec",
    "m",
    "k",
    "n",
    "threads",
    "users",
    "block",
    "shards",
    "spans",
    "events",
    "chunk_rows",
    "mem_budget",
];

/// Which way a metric is supposed to move.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Direction {
    LowerIsBetter,
    HigherIsBetter,
}

/// A known measurement field: direction plus the relative change
/// tolerated as run-to-run noise before a soft regression is reported.
#[derive(Debug, Clone, Copy)]
pub struct Metric {
    pub key: &'static str,
    pub dir: Direction,
    pub noise: f64,
}

use Direction::{HigherIsBetter as H, LowerIsBetter as L};

/// The measurement vocabulary of every bench row schema in the repo,
/// with noise allowances calibrated to what each metric actually is:
/// wall times on shared runners jitter hugely (±35–60%), byte counts
/// are near-deterministic (±2–5%), ratios of co-measured times cancel
/// most machine noise (±25%).
pub const METRICS: &[Metric] = &[
    Metric { key: "median_s", dir: L, noise: 0.35 },
    Metric { key: "min_s", dir: L, noise: 0.40 },
    Metric { key: "wall_s", dir: L, noise: 0.40 },
    Metric { key: "net_s", dir: L, noise: 0.40 },
    Metric { key: "ns_per_span", dir: L, noise: 0.60 },
    Metric { key: "ns_per_event", dir: L, noise: 0.60 },
    Metric { key: "speedup_vs_1t", dir: H, noise: 0.25 },
    Metric { key: "speedup_vs_scalar_1t", dir: H, noise: 0.25 },
    Metric { key: "sim_bytes", dir: L, noise: 0.02 },
    Metric { key: "real_bytes", dir: L, noise: 0.05 },
    Metric { key: "total_bytes", dir: L, noise: 0.05 },
    Metric { key: "peak_rss", dir: L, noise: 0.60 },
    Metric { key: "user_peak_rss", dir: L, noise: 0.60 },
    Metric { key: "user_peak_part_bytes", dir: L, noise: 0.30 },
    Metric { key: "csp_peak_matrix_bytes", dir: L, noise: 0.30 },
    Metric { key: "shard_spills", dir: L, noise: 0.50 },
    Metric { key: "train_mse", dir: L, noise: 0.50 },
];

/// One parsed bench row: identity string, metrics, bools.
#[derive(Debug, Clone)]
pub struct Row {
    /// `key=value` pairs of the present identity fields, sorted — the
    /// match key across runs.
    pub id: String,
    pub metrics: BTreeMap<&'static str, f64>,
    pub bools: BTreeMap<String, bool>,
}

fn row_identity(v: &Json) -> String {
    let mut parts: Vec<String> = Vec::new();
    for &k in IDENTITY_KEYS {
        match v.get(k) {
            Some(Json::Str(s)) => parts.push(format!("{k}={s}")),
            Some(Json::Num(n)) => parts.push(format!("{k}={n}")),
            _ => {}
        }
    }
    parts.join(" ")
}

/// Parse one run's JSONL text into rows keyed by identity. Non-object
/// lines are rejected; rows without a `bench` field are skipped (other
/// JSONL streams may share a file in hand-rolled setups).
pub fn parse_rows(text: &str, source: &str) -> Result<BTreeMap<String, Row>> {
    let mut out = BTreeMap::new();
    for (i, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let v = Json::parse(line)
            .map_err(|e| Error::Runtime(format!("{source}:{}: {e}", i + 1)))?;
        if v.get("bench").and_then(Json::as_str).is_none() {
            continue;
        }
        let mut metrics = BTreeMap::new();
        for m in METRICS {
            if let Some(x) = v.get(m.key).and_then(Json::as_f64) {
                metrics.insert(m.key, x);
            }
        }
        let mut bools = BTreeMap::new();
        if let Json::Obj(fields) = &v {
            for (k, val) in fields {
                if let Json::Bool(b) = val {
                    bools.insert(k.clone(), *b);
                }
            }
        }
        let row = Row { id: row_identity(&v), metrics, bools };
        out.insert(row.id.clone(), row);
    }
    Ok(out)
}

/// One metric's movement on one matched row.
#[derive(Debug, Clone)]
pub struct MetricDiff {
    pub key: &'static str,
    pub old: f64,
    pub new: f64,
    /// Signed relative change, positive = worse (direction-normalized).
    pub rel_worse: f64,
    /// Worse by more than the metric's noise allowance.
    pub regressed: bool,
    /// Better by more than the noise allowance.
    pub improved: bool,
}

/// One matched row's metric movements.
#[derive(Debug, Clone)]
pub struct RowDiff {
    pub id: String,
    pub metrics: Vec<MetricDiff>,
}

/// One hard-threshold violation (fails CI).
#[derive(Debug, Clone)]
pub struct HardRegression {
    pub id: String,
    pub what: String,
}

/// The full comparison of two runs.
#[derive(Debug)]
pub struct DiffReport {
    pub rows: Vec<RowDiff>,
    /// Identities present in the old run only.
    pub missing: Vec<String>,
    /// Identities present in the new run only.
    pub added: Vec<String>,
    pub hard: Vec<HardRegression>,
}

impl DiffReport {
    pub fn has_hard_regressions(&self) -> bool {
        !self.hard.is_empty()
    }

    pub fn regressions(&self) -> usize {
        self.rows
            .iter()
            .flat_map(|r| &r.metrics)
            .filter(|m| m.regressed)
            .count()
    }

    pub fn improvements(&self) -> usize {
        self.rows
            .iter()
            .flat_map(|r| &r.metrics)
            .filter(|m| m.improved)
            .count()
    }

    /// Human-readable report (what CI tees into the artifact).
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "=== bench diff: {} matched rows, {} regressions, {} improvements, \
             {} missing, {} new, {} HARD ===\n",
            self.rows.len(),
            self.regressions(),
            self.improvements(),
            self.missing.len(),
            self.added.len(),
            self.hard.len()
        ));
        for h in &self.hard {
            out.push_str(&format!("HARD  {}\n      {}\n", h.id, h.what));
        }
        for r in &self.rows {
            for m in &r.metrics {
                if m.regressed || m.improved {
                    out.push_str(&format!(
                        "{} {}\n      {}: {} -> {} ({}{:.1}%)\n",
                        if m.regressed { "WORSE " } else { "BETTER" },
                        r.id,
                        m.key,
                        fmt(m.old),
                        fmt(m.new),
                        if m.rel_worse >= 0.0 { "+" } else { "" },
                        m.rel_worse * 100.0
                    ));
                }
            }
        }
        for id in &self.missing {
            out.push_str(&format!("MISSING (in old run only) {id}\n"));
        }
        for id in &self.added {
            out.push_str(&format!("NEW (no baseline yet)     {id}\n"));
        }
        if self.hard.is_empty() {
            out.push_str("hard thresholds: all clear\n");
        } else {
            out.push_str(&format!(
                "hard thresholds: {} VIOLATION(S) — failing\n",
                self.hard.len()
            ));
        }
        out
    }

    /// Machine-readable JSONL of the same findings.
    pub fn json_rows(&self) -> String {
        let mut out = String::new();
        out.push_str(
            &JsonRow::new()
                .str("kind", "summary")
                .u64("matched", self.rows.len() as u64)
                .u64("regressions", self.regressions() as u64)
                .u64("improvements", self.improvements() as u64)
                .u64("missing", self.missing.len() as u64)
                .u64("added", self.added.len() as u64)
                .u64("hard", self.hard.len() as u64)
                .bool("fail", self.has_hard_regressions())
                .finish(),
        );
        out.push('\n');
        for h in &self.hard {
            out.push_str(
                &JsonRow::new()
                    .str("kind", "hard")
                    .str("id", &h.id)
                    .str("what", &h.what)
                    .finish(),
            );
            out.push('\n');
        }
        for r in &self.rows {
            for m in r.metrics.iter().filter(|m| m.regressed || m.improved) {
                out.push_str(
                    &JsonRow::new()
                        .str("kind", if m.regressed { "regression" } else { "improvement" })
                        .str("id", &r.id)
                        .str("metric", m.key)
                        .f64("old", m.old, 6)
                        .f64("new", m.new, 6)
                        .f64("rel_worse", m.rel_worse, 4)
                        .finish(),
                );
                out.push('\n');
            }
        }
        for id in &self.missing {
            out.push_str(&JsonRow::new().str("kind", "missing").str("id", id).finish());
            out.push('\n');
        }
        for id in &self.added {
            out.push_str(&JsonRow::new().str("kind", "added").str("id", id).finish());
            out.push('\n');
        }
        out
    }
}

fn fmt(v: f64) -> String {
    if v == 0.0 {
        "0".into()
    } else if v.abs() >= 1e6 || v.abs() < 1e-3 {
        format!("{v:.3e}")
    } else {
        format!("{v:.4}")
    }
}

/// Does `id` carry `key=value`?
fn id_has(id: &str, key: &str, value: &str) -> bool {
    id.split(' ').any(|p| p == format!("{key}={value}"))
}

fn id_field<'a>(id: &'a str, key: &str) -> Option<&'a str> {
    id.split(' ')
        .find_map(|p| p.strip_prefix(key)?.strip_prefix('='))
}

/// The hard rules guarding the repo's headline numbers. These fire on
/// the *new* run's absolute values (plus one relative collapse guard),
/// so a regression fails even if the baseline had already drifted.
fn hard_regressions(old: &Row, new: &Row) -> Vec<HardRegression> {
    let mut out = Vec::new();
    let id = &new.id;
    // Step-2 masking must keep its ≥ 2× speedup at 4 threads (the
    // ROADMAP's "one to watch"; Tab. 4 of the paper is the 10000×
    // headline this multi-thread path feeds).
    if id_has(id, "bench", "step2_mask_scaling") && id_field(id, "threads") == Some("4") {
        if let Some(&s) = new.metrics.get("speedup_vs_1t") {
            if s < 2.0 {
                out.push(HardRegression {
                    id: id.clone(),
                    what: format!("speedup_vs_1t {s:.2} < 2.0 (hard floor at 4 threads)"),
                });
            }
        }
    }
    // The GEMM micro-kernel's SIMD advantage must not collapse: never
    // below scalar, and never below 60% of the baseline ratio.
    if id_has(id, "bench", "gemm_kernel")
        && id_field(id, "threads") == Some("1")
        && id_field(id, "isa").is_some_and(|i| i != "scalar")
    {
        if let Some(&s) = new.metrics.get("speedup_vs_scalar_1t") {
            if s < 1.0 {
                out.push(HardRegression {
                    id: id.clone(),
                    what: format!("speedup_vs_scalar_1t {s:.2} < 1.0 (SIMD slower than scalar)"),
                });
            } else if let Some(&old_s) = old.metrics.get("speedup_vs_scalar_1t") {
                if old_s > 0.0 && s < old_s * 0.6 {
                    out.push(HardRegression {
                        id: id.clone(),
                        what: format!(
                            "speedup_vs_scalar_1t collapsed {old_s:.2} -> {s:.2} \
                             (below 60% of baseline)"
                        ),
                    });
                }
            }
        }
    }
    // Determinism flags may only flip towards true.
    for (k, &was) in &old.bools {
        if was {
            if let Some(false) = new.bools.get(k).copied() {
                out.push(HardRegression {
                    id: id.clone(),
                    what: format!("{k} flipped true -> false"),
                });
            }
        }
    }
    out
}

/// Diff two runs given their JSONL text (old = baseline, new = current).
pub fn diff_streams(old_text: &str, new_text: &str) -> Result<DiffReport> {
    let old = parse_rows(old_text, "old")?;
    let new = parse_rows(new_text, "new")?;
    let mut rows = Vec::new();
    let mut hard = Vec::new();
    for (id, n) in &new {
        let Some(o) = old.get(id) else { continue };
        let mut metrics = Vec::new();
        for m in METRICS {
            let (Some(&ov), Some(&nv)) = (o.metrics.get(m.key), n.metrics.get(m.key)) else {
                continue;
            };
            // Relative worsening, normalized so positive is always bad.
            let rel_worse = if ov.abs() < 1e-12 {
                0.0
            } else {
                match m.dir {
                    Direction::LowerIsBetter => (nv - ov) / ov.abs(),
                    Direction::HigherIsBetter => (ov - nv) / ov.abs(),
                }
            };
            metrics.push(MetricDiff {
                key: m.key,
                old: ov,
                new: nv,
                rel_worse,
                regressed: rel_worse > m.noise,
                improved: rel_worse < -m.noise,
            });
        }
        hard.extend(hard_regressions(o, n));
        rows.push(RowDiff { id: id.clone(), metrics });
    }
    let missing: Vec<String> = old.keys().filter(|k| !new.contains_key(*k)).cloned().collect();
    let added: Vec<String> = new.keys().filter(|k| !old.contains_key(*k)).cloned().collect();
    Ok(DiffReport { rows, missing, added, hard })
}

/// [`diff_streams`] over files.
pub fn diff_files(old_path: &Path, new_path: &Path) -> Result<DiffReport> {
    let read = |p: &Path| {
        std::fs::read_to_string(p)
            .map_err(|e| Error::Runtime(format!("bench diff: cannot read {}: {e}", p.display())))
    };
    diff_streams(&read(old_path)?, &read(new_path)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    const OLD: &str = concat!(
        r#"{"bench":"step2_mask_scaling","m":512,"n":256,"block":128,"users":8,"threads":4,"median_s":0.5,"speedup_vs_1t":3.1,"bit_identical_vs_1t":true}"#,
        "\n",
        r#"{"bench":"gemm_kernel","shape":"wide-lsa","m":64,"k":4096,"n":64,"isa":"avx2","threads":1,"median_s":0.01,"speedup_vs_scalar_1t":4.0}"#,
        "\n",
        r#"{"bench":"fig5_transport","transport":"tcp","shards":4,"wall_s":1.0,"real_bytes":1000000,"peak_rss":123456789}"#,
        "\n",
    );

    fn edit(src: &str, from: &str, to: &str) -> String {
        assert!(src.contains(from), "test fixture drift: {from}");
        src.replace(from, to)
    }

    #[test]
    fn identical_runs_are_clean() {
        let d = diff_streams(OLD, OLD).unwrap();
        assert_eq!(d.rows.len(), 3);
        assert_eq!(d.regressions(), 0);
        assert_eq!(d.improvements(), 0);
        assert!(!d.has_hard_regressions());
        assert!(d.missing.is_empty() && d.added.is_empty());
    }

    #[test]
    fn noise_sized_drift_is_ignored_but_real_drift_reported() {
        // +20% median_s: inside the 35% allowance.
        let new = edit(OLD, r#""median_s":0.5"#, r#""median_s":0.6"#);
        let d = diff_streams(OLD, &new).unwrap();
        assert_eq!(d.regressions(), 0, "{}", d.render());
        // +100% median_s: reported as a soft regression, not hard.
        let new = edit(OLD, r#""median_s":0.5"#, r#""median_s":1.0"#);
        let d = diff_streams(OLD, &new).unwrap();
        assert_eq!(d.regressions(), 1);
        assert!(!d.has_hard_regressions());
        assert!(d.render().contains("WORSE"));
        // Halving a wall time is an improvement.
        let new = edit(OLD, r#""wall_s":1.0"#, r#""wall_s":0.4"#);
        let d = diff_streams(OLD, &new).unwrap();
        assert_eq!(d.improvements(), 1);
    }

    #[test]
    fn hard_thresholds_fail_the_diff() {
        // Step-2 speedup below the 2× floor at 4 threads.
        let new = edit(OLD, r#""speedup_vs_1t":3.1"#, r#""speedup_vs_1t":1.4"#);
        let d = diff_streams(OLD, &new).unwrap();
        assert!(d.has_hard_regressions(), "{}", d.render());
        assert!(d.render().contains("HARD"));
        // SIMD ratio collapsing below 60% of baseline (still > 1).
        let new = edit(
            OLD,
            r#""speedup_vs_scalar_1t":4.0"#,
            r#""speedup_vs_scalar_1t":1.5"#,
        );
        let d = diff_streams(OLD, &new).unwrap();
        assert!(d.has_hard_regressions());
        // SIMD slower than scalar is hard regardless of baseline.
        let new = edit(
            OLD,
            r#""speedup_vs_scalar_1t":4.0"#,
            r#""speedup_vs_scalar_1t":0.8"#,
        );
        assert!(diff_streams(OLD, &new).unwrap().has_hard_regressions());
        // Bit-identical flipping false is hard.
        let new = edit(
            OLD,
            r#""bit_identical_vs_1t":true"#,
            r#""bit_identical_vs_1t":false"#,
        );
        let d = diff_streams(OLD, &new).unwrap();
        assert!(d.has_hard_regressions());
        assert!(d.render().contains("bit_identical_vs_1t"));
    }

    #[test]
    fn missing_and_new_rows_are_reported_not_fatal() {
        let mut lines: Vec<&str> = OLD.lines().collect();
        lines.pop();
        let shrunk = format!("{}\n", lines.join("\n"));
        let d = diff_streams(OLD, &shrunk).unwrap();
        assert_eq!(d.missing.len(), 1);
        assert!(!d.has_hard_regressions());
        let grown = format!(
            "{OLD}{}\n",
            r#"{"bench":"tab2_data_ingest","m":100,"n":50,"format":"csv","chunk_rows":10,"wall_s":0.2}"#
        );
        let d = diff_streams(OLD, &grown).unwrap();
        assert_eq!(d.added.len(), 1);
        assert!(!d.has_hard_regressions());
    }

    #[test]
    fn varying_integers_do_not_break_identity() {
        // peak_rss differs wildly between runs — rows must still match.
        let new = edit(OLD, r#""peak_rss":123456789"#, r#""peak_rss":987654321"#);
        let d = diff_streams(OLD, &new).unwrap();
        assert!(d.missing.is_empty() && d.added.is_empty());
        assert_eq!(d.rows.len(), 3);
    }

    #[test]
    fn json_rows_parse_and_carry_the_verdict() {
        let new = edit(OLD, r#""speedup_vs_1t":3.1"#, r#""speedup_vs_1t":1.0"#);
        let d = diff_streams(OLD, &new).unwrap();
        let rows = d.json_rows();
        let first = rows.lines().next().unwrap();
        let v = Json::parse(first).unwrap();
        assert_eq!(v.get("kind").and_then(Json::as_str), Some("summary"));
        assert_eq!(v.get("fail"), Some(&Json::Bool(true)));
        for line in rows.lines() {
            Json::parse(line).unwrap();
        }
    }
}
