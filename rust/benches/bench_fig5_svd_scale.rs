//! Fig. 5(a) — time consumption on the SVD task: FedSVD grows *linearly*
//! with n (m fixed), PPDSVD quadratically, with a >10000× gap at scale.
//!
//! Paper grid: m = 1K, n up to 50M (16.3 h). Scaled grid here + measured
//! per-element extrapolation to the paper's sizes.

use fedsvd::baselines::ppdsvd::estimate_ppdsvd;
use fedsvd::bench::section;
use fedsvd::data::synthetic_powerlaw;
use fedsvd::net::presets;
use fedsvd::paillier;
use fedsvd::protocol::{run_fedsvd, split_columns, FedSvdConfig};
use fedsvd::rng::Xoshiro256;
use fedsvd::util::human_secs;

fn main() {
    section(
        "Fig 5(a)",
        "SVD-task time vs n (m fixed): FedSVD linear, PPDSVD quadratic",
    );

    let m = 64usize;
    println!("-- measured FedSVD runs (m={m}) --");
    println!(
        "{:>8} {:>12} {:>12} {:>14}",
        "n", "wall", "network", "per-element"
    );
    let mut per_elem_s = 0.0;
    for n in [128usize, 256, 512, 1024] {
        let x = synthetic_powerlaw(m, n, 0.01, 5);
        let parts = split_columns(&x, 2).unwrap();
        let cfg = FedSvdConfig {
            block_size: 32,
            secagg_batch_rows: 64,
            recover_v: true,
            ..Default::default()
        };
        let t0 = std::time::Instant::now();
        let out = run_fedsvd(&parts, &cfg).unwrap();
        let wall = t0.elapsed().as_secs_f64();
        per_elem_s = wall / (m * n) as f64;
        println!(
            "{n:>8} {:>12} {:>12} {:>11.2} ns",
            human_secs(wall),
            human_secs(out.net.sim_elapsed_s()),
            per_elem_s * 1e9
        );
    }

    println!("\n-- linearity check: wall time per element should be ~constant --");

    // extrapolation to the paper's axis
    println!("\n-- extrapolation (m=1K; FedSVD from measured per-element cost; PPDSVD from measured Paillier costs) --");
    let mut rng = Xoshiro256::seed_from_u64(1);
    let (pk, sk) = paillier::keygen(1024, &mut rng).unwrap();
    let costs = paillier::measure_op_costs(&pk, &sk, 3).unwrap();
    println!(
        "{:>12} {:>16} {:>18} {:>12}",
        "n", "FedSVD est.", "PPDSVD est.", "speedup"
    );
    for n in [2_000usize, 100_000, 1_000_000, 50_000_000] {
        // FedSVD: masking O(mn·b) + CSP SVD O(min·min·max) amortized —
        // at m=1K ≪ n the SVD is O(m²n); fold into per-element slope ×
        // (1 + m/64 scaling of the measured slope)
        let fed = per_elem_s * (1000.0 / m as f64) * (1000.0 * n as f64);
        let he = estimate_ppdsvd(1000, n, 2, &costs, presets::paper_default(), 2e9);
        println!(
            "{n:>12} {:>16} {:>18} {:>11.0}×",
            human_secs(fed),
            human_secs(he.total_s),
            he.total_s / fed
        );
    }
    println!(
        "\npaper anchors: PPDSVD 53.1 h @1K×2K (10000× slower than FedSVD);\n\
         FedSVD 16.3 h @1K×50M. Check: linear vs quadratic growth + 4-5\n\
         orders-of-magnitude speedup at large n."
    );
}
