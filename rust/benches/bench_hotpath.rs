//! Hot-path micro-benchmarks — the §Perf tracking harness.
//!
//! Covers every layer: native matmul (vs the naive triple loop), the
//! block-masking product, the Step-2 thread-scaling sweep (JSON rows for
//! the perf trajectory), secagg PRG expansion, the CSP SVD, and — when
//! built with `--features pjrt` and artifacts are present — the PJRT tile
//! path. Run before/after every optimization; EXPERIMENTS.md §Perf logs
//! the deltas.

use fedsvd::bench::{bench, section};
use fedsvd::linalg::kernel::available_isas;
use fedsvd::linalg::matmul::matmul_naive;
use fedsvd::linalg::{gemm_with_isa, matmul, svd, CpuBackend, Isa, Mat};
use fedsvd::mask::{block_orthogonal, mask_matrix, mask_matrix_with};
use fedsvd::metrics::jsonl::JsonRow;
use fedsvd::pool::ThreadPool;
use fedsvd::rng::Xoshiro256;
use fedsvd::secagg::SecAggGroup;

#[cfg(feature = "pjrt")]
use fedsvd::linalg::GemmBackend;

fn main() {
    let mut rng = Xoshiro256::seed_from_u64(42);

    section("hotpath/L3", "native matmul vs naive (256³, f64)");
    let a = Mat::gaussian(256, 256, &mut rng);
    let b = Mat::gaussian(256, 256, &mut rng);
    let s_naive = bench("matmul_naive 256", 1, 3, || matmul_naive(&a, &b).unwrap());
    let s_fast = bench("matmul_blocked 256", 1, 5, || matmul(&a, &b).unwrap());
    println!("{}", s_naive.row());
    println!("{}", s_fast.row());
    let flops = 2.0 * 256f64.powi(3);
    println!(
        "blocked: {:.2} GF/s ({:.1}× over naive)",
        flops / s_fast.median_s / 1e9,
        s_naive.median_s / s_fast.median_s
    );

    // ---- GEMM kernel comparison: isa × threads × shape class ----------
    // One JSON row per cell so kernel work can be judged across PRs:
    // `speedup_vs_scalar_1t` is the SIMD win (same shape, scalar 1-thread
    // baseline), `speedup_vs_1t` the thread scaling within an ISA. The
    // wide shape (m ≪ n) is the LSA orientation the column-direction
    // tile grid exists for. Outputs are asserted bit-identical across
    // every (isa, threads) cell — determinism is part of the benchmark.
    section(
        "hotpath/L3",
        "GEMM kernel comparison: isa × threads × shape — JSON rows",
    );
    {
        let shapes: [(&str, usize, usize, usize); 3] = [
            ("square", 512, 512, 512),
            ("tall", 4096, 256, 64),
            ("wide", 64, 256, 8192),
        ];
        for (class, m, k, n) in shapes {
            let a = Mat::gaussian(m, k, &mut rng);
            let b = Mat::gaussian(k, n, &mut rng);
            let mut scalar_1t = 0.0f64;
            let mut reference: Option<Mat> = None;
            // available_isas() lists scalar last; reverse so the scalar
            // 1-thread baseline is measured before the SIMD rows need it
            let mut isas = available_isas();
            isas.reverse();
            for isa in isas {
                let mut isa_1t = 0.0f64;
                for threads in [1usize, 2, 4] {
                    let pool = ThreadPool::new(threads);
                    let popt = if threads > 1 { Some(&pool) } else { None };
                    let mut c = Mat::zeros(m, n);
                    let s = bench(
                        &format!("gemm {class} {} {threads}t", isa.name()),
                        1,
                        3,
                        || gemm_with_isa(isa, 1.0, &a, false, &b, false, 0.0, &mut c, popt).unwrap(),
                    );
                    println!("{}", s.row());
                    match reference.as_ref() {
                        Some(r) => assert!(
                            fedsvd::util::bits_equal(r.data(), c.data()),
                            "{class}: isa={} threads={threads} changed output bits!",
                            isa.name()
                        ),
                        None => reference = Some(c),
                    }
                    if isa == Isa::Scalar && threads == 1 {
                        scalar_1t = s.median_s;
                    }
                    if threads == 1 {
                        isa_1t = s.median_s;
                    }
                    println!(
                        "{}",
                        JsonRow::new()
                            .str("bench", "gemm_kernel")
                            .str("shape", class)
                            .u64("m", m as u64)
                            .u64("k", k as u64)
                            .u64("n", n as u64)
                            .str("isa", isa.name())
                            .u64("threads", threads as u64)
                            .f64("median_s", s.median_s, 6)
                            .f64("min_s", s.min_s, 6)
                            .f64("speedup_vs_1t", isa_1t / s.median_s, 3)
                            .f64("speedup_vs_scalar_1t", scalar_1t / s.median_s, 3)
                            .finish()
                    );
                }
            }
        }
    }

    section("hotpath/L3", "block-masking product P·X·Q (m=512, n=512, b=64)");
    let p = block_orthogonal(512, 64, 1).unwrap();
    let q = block_orthogonal(512, 64, 2).unwrap();
    let x = Mat::gaussian(512, 512, &mut rng);
    let qi = q.row_slice(0, 512).unwrap();
    let s_mask = bench("mask_matrix 512", 1, 3, || {
        mask_matrix(&p, &x, &qi).unwrap()
    });
    println!("{}", s_mask.row());
    let mask_flops = 2.0 * (512.0 * 512.0 * 64.0) * 2.0;
    println!("masking: {:.2} GF/s effective", mask_flops / s_mask.median_s / 1e9);

    // ---- Step-2 masking thread-scaling sweep (acceptance workload) -----
    // 4096×4096 federated matrix, two users (2048 columns each), block 64.
    // One JSON row per thread count so future PRs can chart the perf
    // trajectory; outputs are asserted bit-identical across counts.
    section(
        "hotpath/L3",
        "Step-2 masking thread scaling (4096×4096, 2 users, b=64) — JSON rows",
    );
    {
        let (m, n, blk) = (4096usize, 4096usize, 64usize);
        let p = block_orthogonal(m, blk, 3).unwrap();
        let q = block_orthogonal(n, blk, 4).unwrap();
        let x1 = Mat::gaussian(m, n / 2, &mut rng);
        let x2 = Mat::gaussian(m, n - n / 2, &mut rng);
        let qi1 = q.row_slice(0, n / 2).unwrap();
        let qi2 = q.row_slice(n / 2, n).unwrap();
        let mut base_median = 0.0f64;
        let mut reference: Option<(Mat, Mat)> = None;
        for threads in [1usize, 2, 4, 8] {
            let backend = CpuBackend::with_threads(threads);
            let s = bench(&format!("step2 mask 4096² {threads}t"), 1, 3, || {
                (
                    mask_matrix_with(&p, &x1, &qi1, &backend).unwrap(),
                    mask_matrix_with(&p, &x2, &qi2, &backend).unwrap(),
                )
            });
            println!("{}", s.row());
            let out = (
                mask_matrix_with(&p, &x1, &qi1, &backend).unwrap(),
                mask_matrix_with(&p, &x2, &qi2, &backend).unwrap(),
            );
            let bit_identical = if let Some((r1, r2)) = reference.as_ref() {
                let same = fedsvd::util::bits_equal(r1.data(), out.0.data())
                    && fedsvd::util::bits_equal(r2.data(), out.1.data());
                assert!(same, "thread count {threads} changed output bits!");
                same
            } else {
                base_median = s.median_s;
                true
            };
            if reference.is_none() {
                reference = Some(out);
            }
            println!(
                "{}",
                JsonRow::new()
                    .str("bench", "step2_mask_scaling")
                    .u64("m", m as u64)
                    .u64("n", n as u64)
                    .u64("block", blk as u64)
                    .u64("users", 2)
                    .u64("threads", threads as u64)
                    .f64("median_s", s.median_s, 6)
                    .f64("min_s", s.min_s, 6)
                    .f64("speedup_vs_1t", base_median / s.median_s, 3)
                    .bool("bit_identical_vs_1t", bit_identical)
                    .finish()
            );
        }
    }

    // ---- Tracing overhead: off vs flight-recorder vs full JSONL -------
    // One JSON row per mode so the cost of the obs layer is tracked in
    // the perf trajectory like every other knob. "off" measures the
    // instrumented-seam cost with no party tracer installed (the state
    // every bench and sequential run is in), "flight" the always-on
    // ring-buffer sink, "jsonl" the opt-in per-event file sink.
    section(
        "hotpath/obs",
        "tracing overhead: off vs flight-recorder vs JSONL — JSON rows",
    );
    {
        use fedsvd::obs::{self, Tracer};
        let spans = 20_000u64;
        let trace_tmp = std::env::temp_dir().join(format!(
            "fedsvd-bench-obs-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&trace_tmp);
        for mode in ["off", "flight", "jsonl"] {
            let tracer = match mode {
                "off" => None,
                "flight" => Some(Tracer::with_sink_dir("bench", 0, None)),
                _ => Some(Tracer::with_sink_dir("bench", 0, Some(&trace_tmp))),
            };
            let guard = tracer.map(obs::set_current);
            let start = std::time::Instant::now();
            for _ in 0..spans {
                obs::with_current(|t| t.span_enter("bench_span", None));
                obs::with_current(|t| t.span_leave("bench_span", None, None));
            }
            let elapsed = start.elapsed().as_secs_f64();
            drop(guard);
            let ns_per_span = elapsed / spans as f64 * 1e9;
            println!("obs {mode}: {ns_per_span:.1} ns/span");
            println!(
                "{}",
                JsonRow::new()
                    .str("bench", "obs_overhead")
                    .str("mode", mode)
                    .u64("spans", spans)
                    .f64("wall_s", elapsed, 6)
                    .f64("ns_per_span", ns_per_span, 1)
                    .finish()
            );
        }
        fedsvd::obs::flight_clear();
        let _ = std::fs::remove_dir_all(&trace_tmp);
    }

    // ---- Live metrics overhead: off vs on vs on-while-scraped ---------
    // One event = one on_send + one on_recv, the two feeds on the
    // transport's per-frame hot path. "off" is the default state (no
    // `--metrics-addr`), "on" the registry cost alone, "on_scraped" the
    // same while another thread renders `/metrics` in a tight loop —
    // scrapes must not stall the data plane.
    section(
        "hotpath/obs",
        "live metrics overhead: off vs on vs on+scraped — JSON rows",
    );
    {
        use fedsvd::obs::metrics_live;
        use std::sync::atomic::{AtomicBool, Ordering};
        use std::sync::Arc;
        let events = 200_000u64;
        for mode in ["off", "on", "on_scraped"] {
            metrics_live::reset_for_tests();
            metrics_live::set_enabled(mode != "off");
            let stop = Arc::new(AtomicBool::new(false));
            let scraper = (mode == "on_scraped").then(|| {
                let stop = Arc::clone(&stop);
                std::thread::spawn(move || {
                    let mut scrapes = 0u64;
                    while !stop.load(Ordering::Relaxed) {
                        std::hint::black_box(metrics_live::render_metrics());
                        scrapes += 1;
                    }
                    scrapes
                })
            });
            let start = std::time::Instant::now();
            for i in 0..events {
                metrics_live::on_send(1_000 + (i % 4), 4 * 1024);
                metrics_live::on_recv(4 * 1024);
            }
            let elapsed = start.elapsed().as_secs_f64();
            stop.store(true, Ordering::Relaxed);
            let scrapes = scraper.map_or(0, |h| h.join().unwrap_or(0));
            metrics_live::set_enabled(false);
            metrics_live::reset_for_tests();
            let ns_per_event = elapsed / events as f64 * 1e9;
            println!("metrics {mode}: {ns_per_event:.1} ns/event ({scrapes} scrapes)");
            println!(
                "{}",
                JsonRow::new()
                    .str("bench", "metrics_live_overhead")
                    .str("mode", mode)
                    .u64("events", events)
                    .u64("concurrent_scrapes", scrapes)
                    .f64("wall_s", elapsed, 6)
                    .f64("ns_per_event", ns_per_event, 1)
                    .finish()
            );
        }
    }

    section("hotpath/L3", "secagg mask expansion + aggregate (2 users, 64×512)");
    let seeds = vec![vec![0, 7], vec![7, 0]];
    let group = SecAggGroup::from_seeds(seeds).unwrap();
    let data: Vec<f64> = (0..64 * 512).map(|i| i as f64 * 0.01).collect();
    let s_secagg = bench("secagg share+agg", 1, 5, || {
        let s0 = group.mask_share(0, &data, 0).unwrap();
        let s1 = group.mask_share(1, &data, 0).unwrap();
        group.aggregate(&[s0, s1]).unwrap()
    });
    println!("{}", s_secagg.row());
    println!(
        "secagg throughput: {:.1} M elems/s",
        (2 * data.len()) as f64 / s_secagg.median_s / 1e6
    );

    section("hotpath/L3", "CSP SVD (Jacobi+QR) 192×192 / 384×96");
    let sq = Mat::gaussian(192, 192, &mut rng);
    let s_svd = bench("svd 192x192", 0, 3, || svd(&sq).unwrap());
    println!("{}", s_svd.row());
    let tall = Mat::gaussian(384, 96, &mut rng);
    let s_svd2 = bench("svd 384x96", 0, 3, || svd(&tall).unwrap());
    println!("{}", s_svd2.row());

    #[cfg(feature = "pjrt")]
    {
        use fedsvd::runtime::TileEngine;
        section("hotpath/L1+runtime", "PJRT tile path (needs `make artifacts`)");
        match TileEngine::from_artifacts() {
            Ok(engine) => {
                let ta = Mat::gaussian(64, 64, &mut rng);
                let tb = Mat::gaussian(64, 64, &mut rng);
                let tc = Mat::gaussian(64, 64, &mut rng);
                let s_tile = bench("pjrt matmul 64", 2, 10, || engine.matmul(&ta, &tb).unwrap());
                println!("{}", s_tile.row());
                let s_fused = bench("pjrt fused mask_tile 64", 2, 10, || {
                    engine.mask_tile(&ta, &tb, &tc).unwrap()
                });
                println!("{}", s_fused.row());
                let s_native_tile = bench("cpu 64 (ref)", 2, 10, || {
                    CpuBackend::global().mask_tile(&ta, &tb, &tc).unwrap()
                });
                println!("{}", s_native_tile.row());
                println!(
                    "note: interpret-mode Pallas on CPU measures dispatch overhead,\n\
                     not TPU performance — see DESIGN.md §Hardware-Adaptation for\n\
                     the VMEM/MXU estimates that stand in for real-TPU numbers."
                );
            }
            Err(e) => println!("skipped ({e})"),
        }
    }
    #[cfg(not(feature = "pjrt"))]
    section(
        "hotpath/L1+runtime",
        "PJRT tile path compiled out (build with --features pjrt)",
    );
}
