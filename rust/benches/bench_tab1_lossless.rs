//! Tab. 1 — lossless evaluation on the SVD task and the three
//! applications, across the four dataset families.
//!
//! Columns reproduced: SVD (FedPCA vs FedSVD singular-vector RMSE),
//! PCA/LSA (FedPCA vs WDA vs FedSVD projection distance, r=10), and
//! LR (SGD @10/100/1000 epochs vs FedSVD-LR train MSE). Plus the §5.2
//! reconstruction-MAPE line.

use fedsvd::apps::lr::{centralized_lr, run_federated_lr};
use fedsvd::apps::pca::projection_distance;
use fedsvd::baselines::fedpca::{run_fedpca, DpParams};
use fedsvd::baselines::sgd_lr::{run_sgd_lr, SgdFramework};
use fedsvd::baselines::wda::run_wda;
use fedsvd::bench::section;
use fedsvd::data;
use fedsvd::linalg::{svd, CpuBackend, Mat, SvdResult};
use fedsvd::net::presets;
use fedsvd::paillier::OpCosts;
use fedsvd::protocol::{run_fedsvd, split_columns, FedSvdConfig};
use fedsvd::util::{mape, rmse};

fn datasets() -> Vec<(&'static str, Mat)> {
    vec![
        ("Wine", data::wine_like(12, 600, 1)),
        ("MNIST", data::mnist_like(64, 400, 1)),
        ("ML100K", data::movielens_like(80, 300, 1)),
        ("Synthetic", data::synthetic_powerlaw(48, 300, 1.0, 1)),
    ]
}

fn cfg() -> FedSvdConfig {
    FedSvdConfig {
        block_size: 16,
        secagg_batch_rows: 64,
        ..Default::default()
    }
}

fn main() {
    svd_columns();
    pca_lsa_columns();
    lr_columns();
    reconstruction_line();
}

/// Sign-aligned singular-vector RMSE for the top-k (paper's SVD metric).
fn sv_rmse(u_a: &Mat, u_b: &Mat, k: usize) -> f64 {
    let mut acc = 0.0;
    let mut cnt = 0usize;
    for j in 0..k.min(u_a.cols()).min(u_b.cols()) {
        let va = u_a.col(j);
        let vb = u_b.col(j);
        let dot: f64 = va.iter().zip(&vb).map(|(x, y)| x * y).sum();
        let s = if dot >= 0.0 { 1.0 } else { -1.0 };
        for (x, y) in va.iter().zip(&vb) {
            acc += (x - s * y) * (x - s * y);
            cnt += 1;
        }
    }
    (acc / cnt as f64).sqrt()
}

fn svd_columns() {
    section("Tab 1 (SVD)", "singular-vector RMSE vs centralized: FedPCA(DP) vs FedSVD");
    println!("{:<12} {:>14} {:>14}", "dataset", "FedPCA", "FedSVD");
    for (name, x) in datasets() {
        let parts = split_columns(&x, 2).unwrap();
        let truth = svd(&x).unwrap();
        let k = 4usize;

        let fed = run_fedsvd(&parts, &cfg()).unwrap();
        // top-k vectors have separated σ on these generators → sign-aligned
        let fed_err = sv_rmse(fed.u.as_ref().unwrap(), &truth.u, k).max(1e-16);

        let dp = run_fedpca(&parts, k, DpParams::default(), presets::paper_default(), 3)
            .unwrap();
        let dp_err = sv_rmse(&dp.u_k, &truth.u, k);

        println!("{name:<12} {dp_err:>14.3e} {fed_err:>14.3e}");
    }
    println!("\npaper check: FedSVD ~1e-10..1e-15, DP ~1e-1; ≥9 orders of magnitude gap");
}

fn pca_lsa_columns() {
    section(
        "Tab 1 (PCA/LSA)",
        "projection distance ‖UUᵀ−ÛÛᵀ‖₂ (r=10): FedPCA vs WDA vs FedSVD",
    );
    println!(
        "{:<12} {:>14} {:>14} {:>14}",
        "dataset", "FedPCA", "WDA", "FedSVD"
    );
    for (name, x) in datasets() {
        let parts = split_columns(&x, 2).unwrap();
        let r = 10usize.min(x.rows() - 1);
        let truth = svd(&x).unwrap().truncate(r);

        let fed = run_fedsvd(&parts, &cfg()).unwrap();
        let fed_err =
            projection_distance(&fed.u.unwrap().take_cols(r), &truth.u).unwrap().max(1e-16);

        let dp = run_fedpca(&parts, r, DpParams::default(), presets::paper_default(), 5)
            .unwrap();
        let dp_err = projection_distance(&dp.u_k, &truth.u).unwrap();

        let wda = run_wda(&parts, r, presets::paper_default()).unwrap();
        let wda_err = projection_distance(&wda.u_k, &truth.u).unwrap();

        println!("{name:<12} {dp_err:>14.3e} {wda_err:>14.3e} {fed_err:>14.3e}");
    }
    println!("\npaper check: FedSVD ≥10 orders below both baselines; WDA between DP and FedSVD");
}

fn lr_columns() {
    section(
        "Tab 1 (LR)",
        "train MSE: SGD @10/100/1000 epochs (FATE&SML trajectory) vs FedSVD-LR",
    );
    let costs = OpCosts {
        encrypt_s: 1e-3,
        decrypt_s: 1e-3,
        add_s: 1e-5,
        mul_plain_s: 5e-4,
        ciphertext_bytes: 256,
    };
    println!(
        "{:<12} {:>12} {:>12} {:>12} {:>12}",
        "dataset", "SGD 10ep", "SGD 100ep", "SGD 1000ep", "FedSVD"
    );
    for (name, x) in datasets() {
        // regression target: first row of data as labels over the rest
        let xt = x.transpose(); // samples × features
        let m = xt.rows();
        let n = xt.cols().min(24);
        let xf = xt.slice(0, m, 0, n);
        let y: Vec<f64> = (0..m)
            .map(|i| xf.row(i).iter().sum::<f64>() * 0.3 + (i % 7) as f64 * 0.01)
            .collect();

        let sgd = run_sgd_lr(&xf, &y, 1000, 0.5, 2, SgdFramework::Fate, &costs,
            presets::paper_default()).unwrap();
        let mse10 = sgd.mse_per_epoch[9];
        let mse100 = sgd.mse_per_epoch[99];
        let mse1000 = sgd.mse_per_epoch[999];

        let parts = split_columns(&xf, 2).unwrap();
        let fed = run_federated_lr(&parts, &y, 0, &cfg(), CpuBackend::global()).unwrap();

        println!(
            "{name:<12} {mse10:>12.4e} {mse100:>12.4e} {mse1000:>12.4e} {:>12.4e}",
            fed.train_mse
        );
    }
    println!("\npaper check: MSE decreases with epochs; FedSVD (closed form) is the floor");
}

fn reconstruction_line() {
    section("§5.2", "reconstruction error ‖X−UΣVᵀ‖ as MAPE of raw data");
    for (name, x) in datasets() {
        let parts = split_columns(&x, 2).unwrap();
        let out = run_fedsvd(&parts, &cfg()).unwrap();
        let mut v = out.v_parts[0].clone();
        for p in &out.v_parts[1..] {
            v = v.hcat(p).unwrap();
        }
        let rec = SvdResult {
            u: out.u.unwrap(),
            s: out.s,
            vt: v,
        }
        .reconstruct();
        println!(
            "{name:<12} MAPE {:.3e}   σ-RMSE {:.3e}",
            mape(x.data(), rec.data()),
            rmse(rec.data(), x.data())
        );
    }
    println!("\npaper check: MAPE ≈ 1e-8 (\"0.000001% of the raw data\") or better");
}
