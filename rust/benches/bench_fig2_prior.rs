//! Fig. 2 — "Quantifying accuracy loss and performance penalty" of prior
//! federated SVD work.
//!
//! (a) DP-SVD error vs FedSVD on four datasets (δ = 0.01 per the figure).
//! (b) HE-based SVD time blow-up: measured small-scale runs + the
//!     measured-cost extrapolation that shows the quadratic wall
//!     (paper: 15.1 years at 1K×100K).

use fedsvd::apps::pca::projection_distance;
use fedsvd::baselines::fedpca::{run_fedpca, DpParams};
use fedsvd::baselines::ppdsvd::{estimate_ppdsvd, run_ppdsvd};
use fedsvd::bench::section;
use fedsvd::data::Dataset;
use fedsvd::linalg::svd;
use fedsvd::net::presets;
use fedsvd::paillier;
use fedsvd::protocol::{run_fedsvd, split_columns, FedSvdConfig};
use fedsvd::rng::Xoshiro256;
use fedsvd::util::human_secs;

fn main() {
    fig2a();
    fig2b();
}

fn fig2a() {
    section(
        "Fig 2(a)",
        "DP-SVD (δ=0.01) error vs FedSVD, top-4 subspace projection distance",
    );
    println!(
        "{:<12} {:>14} {:>14} {:>12}",
        "dataset", "FedSVD err", "DP-SVD err", "gap"
    );
    for ds in [
        Dataset::Wine,
        Dataset::Mnist,
        Dataset::Ml100k,
        Dataset::Synthetic,
    ] {
        // scaled shapes with ≥16 features so top-4 is meaningful
        let x = match ds {
            Dataset::Wine => fedsvd::data::wine_like(12, 400, 1),
            Dataset::Mnist => fedsvd::data::mnist_like(64, 300, 1),
            Dataset::Ml100k => fedsvd::data::movielens_like(60, 200, 1),
            Dataset::Synthetic => fedsvd::data::synthetic_powerlaw(40, 200, 1.0, 1),
        };
        let parts = split_columns(&x, 2).unwrap();
        let truth = svd(&x).unwrap().truncate(4);

        let fed = run_fedsvd(
            &parts,
            &FedSvdConfig {
                block_size: 16,
                ..Default::default()
            },
        )
        .unwrap();
        let fed_err = projection_distance(&fed.u.unwrap().take_cols(4), &truth.u)
            .unwrap()
            .max(1e-16);

        let dp = run_fedpca(
            &parts,
            4,
            DpParams {
                epsilon: 0.1,
                delta: 0.01,
            },
            presets::paper_default(),
            7,
        )
        .unwrap();
        let dp_err = projection_distance(&dp.u_k, &truth.u).unwrap();

        println!(
            "{:<12} {:>14.3e} {:>14.3e} {:>11.1e}×",
            ds.name(),
            fed_err,
            dp_err,
            dp_err / fed_err
        );
    }
}

fn fig2b() {
    section(
        "Fig 2(b)",
        "HE-based SVD time vs matrix width (measured + extrapolated)",
    );
    let mut rng = Xoshiro256::seed_from_u64(2);
    let (pk, sk) = paillier::keygen(1024, &mut rng).unwrap();
    let costs = paillier::measure_op_costs(&pk, &sk, 3).unwrap();
    println!("measured Paillier-1024 costs: encrypt {:.2} ms, decrypt {:.2} ms, ct {} B",
        costs.encrypt_s * 1e3, costs.decrypt_s * 1e3, costs.ciphertext_bytes);

    println!("\n-- real runs (toy 256-bit keys, m=16) --");
    println!("{:>8} {:>14}", "n", "PPDSVD time");
    for n in [32usize, 64, 128] {
        let x = fedsvd::data::synthetic_powerlaw(16, n, 0.5, 3);
        let parts = split_columns(&x, 2).unwrap();
        let t0 = std::time::Instant::now();
        run_ppdsvd(&parts, 256, presets::paper_default()).unwrap();
        println!("{n:>8} {:>14}", human_secs(t0.elapsed().as_secs_f64()));
    }

    println!("\n-- extrapolation at 1024-bit keys, m=1K (paper setting) --");
    println!("{:>10} {:>16} {:>16}", "n", "PPDSVD est.", "in years");
    for n in [1_000usize, 2_000, 10_000, 100_000] {
        let est = estimate_ppdsvd(1000, n, 2, &costs, presets::paper_default(), 2e9);
        println!(
            "{n:>10} {:>16} {:>16.4}",
            human_secs(est.total_s),
            est.total_s / (365.25 * 24.0 * 3600.0)
        );
    }
    println!(
        "\npaper anchors: 53.1 h at 1K×2K, ~15.1 years at 1K×100K.\n\
         Shape check: time grows quadratically in n (cross-party covariance\n\
         blocks under HE) and reaches the years scale at n=100K — the wall\n\
         that motivates FedSVD."
    );
}
