//! Tab. 3 — ICA attacks on the masked data.
//!
//! Rows: random-values baseline, ICA and ICA(b) at b ∈ {small, medium,
//! full}. Paper: attacks succeed at b=10, degrade at b=100, fail at
//! b=1000. Scaled here: the matrices are 48–64 signals wide, so "full
//! mixing" (b = d) plays the paper's b=1000 role.

use fedsvd::attack::ica::fast_ica_blockwise;
use fedsvd::attack::score::random_baseline;
use fedsvd::attack::{fast_ica, matched_pearson, IcaOptions};
use fedsvd::bench::section;
use fedsvd::data;
use fedsvd::linalg::Mat;
use fedsvd::mask::block_orthogonal;

fn main() {
    section(
        "Tab 3",
        "ICA attack Pearson (mean of optimal n-to-n matching; the paper's max\n         statistic saturates at scaled-down sizes) vs block size",
    );
    // Dimension/sample ratios mirror the paper's: MNIST 784×10K and
    // ML-100K 1682×943 give the attacker few samples per mixed dimension
    // — the regime where large-b mixing defeats ICA (Tab. 3's b=1000 rows).
    let sets: Vec<(&str, Mat)> = vec![
        ("MNIST", data::mnist_like(196, 280, 3)),
        ("ML-100K", data::movielens_like(240, 140, 3)),
        ("Wine", data::wine_like(12, 900, 3)),
    ];

    println!(
        "{:<16} {:>5} {:>10} {:>10} {:>10}",
        "attack", "b", "MNIST", "ML-100K", "Wine"
    );

    // random baseline row
    {
        let vals: Vec<f64> = sets
            .iter()
            .map(|(_, x)| random_baseline(x, 2, 7).0)
            .collect();
        println!(
            "{:<16} {:>5} {:>10.4} {:>10.4} {:>10.4}",
            "Random Values", "NA", vals[0], vals[1], vals[2]
        );
    }

    for b in [4usize, 24, 240] {
        // blind ICA (attacker ignores block structure)
        let ica: Vec<f64> = sets
            .iter()
            .map(|(_, x)| attack(x, b, false))
            .collect();
        println!(
            "{:<16} {:>5} {:>10.4} {:>10.4} {:>10.4}",
            "ICA", b, ica[0], ica[1], ica[2]
        );
        // ICA(b): attacker knows b
        let icab: Vec<f64> = sets
            .iter()
            .map(|(_, x)| attack(x, b, true))
            .collect();
        println!(
            "{:<16} {:>5} {:>10.4} {:>10.4} {:>10.4}",
            "ICA(b)", b, icab[0], icab[1], icab[2]
        );
    }

    println!(
        "\npaper checks: (1) ICA(b) ≥ ICA (knowing b helps);\n\
         (2) both decrease as b grows; (3) at full mixing the attack sits\n\
         at/near the random baseline — choose b accordingly (§5.4)."
    );
}

fn attack(x: &Mat, b: usize, knows_b: bool) -> f64 {
    let d = x.rows();
    let b_eff = b.min(d);
    let p = block_orthogonal(d, b_eff, 0x7ab3 + b as u64).unwrap();
    let masked = p.mul_dense(x).unwrap();
    let opts = IcaOptions {
        max_iter: 120,
        seed: 9 + b as u64,
        ..Default::default()
    };
    let rec = if knows_b {
        fast_ica_blockwise(&masked, b_eff, opts)
    } else {
        fast_ica(&masked, opts)
    };
    match rec {
        Ok(r) => matched_pearson(&r, x).0,
        Err(_) => 0.0,
    }
}
