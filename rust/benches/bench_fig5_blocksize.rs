//! Fig. 5(e) — impact of the mask block size b on FedSVD's efficiency:
//! time grows slowly with b (mask generation is O(b²n), masking O(mnb))
//! while privacy strengthens (Tab. 3). Accuracy is untouched at every b.

use fedsvd::bench::section;
use fedsvd::data::synthetic_powerlaw;
use fedsvd::linalg::svd;
use fedsvd::protocol::{run_fedsvd, split_columns, FedSvdConfig};
use fedsvd::util::{human_secs, rmse};

fn main() {
    section("Fig 5(e)", "FedSVD time vs block size b (accuracy shown to be b-independent)");
    let m = 96usize;
    let n = 256usize;
    let x = synthetic_powerlaw(m, n, 0.01, 11);
    let parts = split_columns(&x, 2).unwrap();
    let truth = svd(&x).unwrap();

    println!(
        "{:>8} {:>12} {:>12} {:>14}",
        "b", "wall", "network", "σ-RMSE"
    );
    for b in [2usize, 4, 8, 16, 32, 64, 96] {
        let cfg = FedSvdConfig {
            block_size: b,
            secagg_batch_rows: 64,
            ..Default::default()
        };
        let t0 = std::time::Instant::now();
        let out = run_fedsvd(&parts, &cfg).unwrap();
        let wall = t0.elapsed().as_secs_f64();
        println!(
            "{b:>8} {:>12} {:>12} {:>14.2e}",
            human_secs(wall),
            human_secs(out.net.sim_elapsed_s()),
            rmse(&out.s, &truth.s)
        );
    }
    println!(
        "\npaper check: time increases slowly with b; error pinned at the\n\
         f64 floor for every b (losslessness is b-independent; b only\n\
         buys privacy, Tab. 3)"
    );
}
