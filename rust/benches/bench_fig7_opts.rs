//! Fig. 7 / §5.5 — effectiveness of the proposed optimizations.
//!
//! Opt1 (block-based masks), Opt2 (mini-batch secagg), Opt3 (advanced
//! disk offloading). Paper (10K×50K): −73.2% communication, −81.9% time,
//! −95.6% memory vs no optimizations; Opt3 −44.7% time vs OS swap.

use fedsvd::bench::section;
use fedsvd::data::synthetic_powerlaw;
use fedsvd::linalg::Mat;
use fedsvd::protocol::{run_fedsvd, split_columns, FedSvdConfig, OptFlags};
use fedsvd::storage::offload::AccessPattern;
use fedsvd::storage::{OffloadPolicy, OffloadedMat};
use fedsvd::util::{human_bytes, human_secs};

fn main() {
    opts_ablation();
    offloading_ablation();
}

fn opts_ablation() {
    section(
        "Fig 7 (Opt1+Opt2)",
        "communication / time / server memory with and without optimizations",
    );
    // scaled stand-in for the paper's 10K×50K. At paper scale the time
    // budget is compute+serialization-dominated; a low-RTT link keeps the
    // scaled-down run in the same regime (otherwise fixed round-trips
    // would swamp the deltas the figure is about).
    let m = 192usize;
    let n = 960usize; // n ≈ 5m mirrors the paper's 10K×50K aspect ratio
    let x = synthetic_powerlaw(m, n, 0.01, 13);
    let parts = split_columns(&x, 2).unwrap();

    let run = |block_masks: bool, minibatch: bool| {
        let cfg = FedSvdConfig {
            block_size: 32,
            secagg_batch_rows: 24,
            link: fedsvd::net::LinkSpec {
                bandwidth_bps: 1e9,
                rtt_s: 0.005,
            },
            opts: OptFlags {
                block_masks,
                minibatch_secagg: minibatch,
            },
            ..Default::default()
        };
        let t0 = std::time::Instant::now();
        let out = run_fedsvd(&parts, &cfg).unwrap();
        let wall = t0.elapsed().as_secs_f64();
        (
            out.net.total_bytes(),
            wall + out.net.sim_elapsed_s(),
            out.metrics.mem_peak(),
        )
    };

    println!(
        "{:<26} {:>14} {:>12} {:>14}",
        "configuration", "comm", "time", "server mem"
    );
    let (c0, t0_, m0) = run(false, false);
    println!(
        "{:<26} {:>14} {:>12} {:>14}",
        "no optimizations",
        human_bytes(c0),
        human_secs(t0_),
        human_bytes(m0)
    );
    let (c1, t1, m1) = run(true, false);
    println!(
        "{:<26} {:>14} {:>12} {:>14}",
        "+Opt1 (block masks)",
        human_bytes(c1),
        human_secs(t1),
        human_bytes(m1)
    );
    let (c2, t2, m2) = run(true, true);
    println!(
        "{:<26} {:>14} {:>12} {:>14}",
        "+Opt1+Opt2 (mini-batch)",
        human_bytes(c2),
        human_secs(t2),
        human_bytes(m2)
    );
    println!(
        "\nreductions vs no-opt: comm −{:.1}%, time −{:.1}%, memory −{:.1}%",
        100.0 * (1.0 - c2 as f64 / c0 as f64),
        100.0 * (1.0 - t2 / t0_),
        100.0 * (1.0 - m2 as f64 / m0 as f64)
    );
    println!("paper anchors: −73.2% comm, −81.9% time, −95.6% memory");
}

fn offloading_ablation() {
    section(
        "Fig 7 (Opt3) / §5.5",
        "advanced offloading vs swap-like layout-oblivious reads",
    );
    // column-scan workload over a file-backed matrix (the paper's
    // "access by column conflicts with storage by row" case)
    let m = 512usize;
    let n = 512usize;
    let mut rng = fedsvd::rng::Xoshiro256::seed_from_u64(17);
    let x = Mat::gaussian(m, n, &mut rng);
    let dir = std::env::temp_dir().join("fedsvd_fig7_offload");
    std::fs::create_dir_all(&dir).unwrap();

    let mut results = Vec::new();
    for (name, policy) in [
        ("advanced (Opt3)", OffloadPolicy::Advanced),
        ("swap-like", OffloadPolicy::SwapLike),
    ] {
        let off = OffloadedMat::offload(
            &dir.join(format!("{name}.bin").replace(' ', "_")),
            &x,
            policy,
            AccessPattern::ByColBlocks,
        )
        .unwrap();
        let t0 = std::time::Instant::now();
        let mut checksum = 0.0f64;
        for b in 0..off.n_blocks(64) {
            let blk = off.read_block(b * 64, 64).unwrap();
            checksum += blk.data().iter().sum::<f64>();
        }
        let dt = t0.elapsed().as_secs_f64();
        println!("{name:<20} column-scan {}  (checksum {checksum:.3})", human_secs(dt));
        results.push(dt);
    }
    println!(
        "\nadvanced offloading reduces scan time by {:.1}% (paper: −44.7%)",
        100.0 * (1.0 - results[0] / results[1])
    );
}
