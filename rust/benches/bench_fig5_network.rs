//! Fig. 5(c,d) — SVD-task end-to-end time vs network bandwidth and
//! latency: FedSVD is robust across link conditions because its traffic
//! is raw-data-sized (vs ciphertext-inflated HE traffic).

use fedsvd::bench::section;
use fedsvd::data::synthetic_powerlaw;
use fedsvd::net::LinkSpec;
use fedsvd::protocol::{run_fedsvd, split_columns, FedSvdConfig};
use fedsvd::util::human_secs;

fn main() {
    let m = 64usize;
    let n = 256usize;
    let x = synthetic_powerlaw(m, n, 0.01, 9);
    let parts = split_columns(&x, 2).unwrap();

    // run once on the reference link, reprice for the sweeps (identical
    // traffic; only the link model changes — same method as tc-shaping)
    let cfg = FedSvdConfig {
        block_size: 32,
        ..Default::default()
    };
    let t0 = std::time::Instant::now();
    let out = run_fedsvd(&parts, &cfg).unwrap();
    let wall = t0.elapsed().as_secs_f64();

    section("Fig 5(c)", "time vs bandwidth (RTT fixed 50 ms)");
    println!("{:>14} {:>12} {:>12} {:>12}", "bandwidth", "compute", "network", "total");
    for bw_mbps in [10.0f64, 100.0, 1_000.0, 10_000.0] {
        let net_s = out.net.reprice(LinkSpec {
            bandwidth_bps: bw_mbps * 1e6,
            rtt_s: 0.05,
        });
        println!(
            "{:>11} Mbps {:>12} {:>12} {:>12}",
            bw_mbps,
            human_secs(wall),
            human_secs(net_s),
            human_secs(wall + net_s)
        );
    }

    section("Fig 5(d)", "time vs RTT (bandwidth fixed 1 Gb/s)");
    println!("{:>10} {:>12} {:>12} {:>12}", "RTT", "compute", "network", "total");
    for rtt_ms in [1.0f64, 10.0, 50.0, 200.0] {
        let net_s = out.net.reprice(LinkSpec {
            bandwidth_bps: 1e9,
            rtt_s: rtt_ms / 1e3,
        });
        println!(
            "{:>7} ms {:>12} {:>12} {:>12}",
            rtt_ms,
            human_secs(wall),
            human_secs(net_s),
            human_secs(wall + net_s)
        );
    }
    println!(
        "\npaper check: total time degrades gracefully — bandwidth matters\n\
         below ~100 Mbps, RTT adds rounds×latency; no cliff (vs HE whose\n\
         inflated traffic multiplies both sensitivities)"
    );
}
