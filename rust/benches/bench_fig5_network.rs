//! Fig. 5(c,d) — SVD-task end-to-end time vs network bandwidth and
//! latency: FedSVD is robust across link conditions because its traffic
//! is raw-data-sized (vs ciphertext-inflated HE traffic).
//!
//! Plus: `fig5_transport` JSON rows (transport × shards × wall ×
//! bytes) comparing the simulated in-process fabric against real
//! loopback TCP — the per-PR tracker for how far the simulated byte
//! model sits from actual wire bytes (frame headers, handshakes).

use fedsvd::bench::section;
use fedsvd::cluster::{run_fedsvd_cluster, run_fedsvd_cluster_tcp, ClusterConfig};
use fedsvd::data::synthetic_powerlaw;
use fedsvd::linalg::CpuBackend;
use fedsvd::metrics::jsonl::JsonRow;
use fedsvd::net::LinkSpec;
use fedsvd::protocol::{run_fedsvd, split_columns, FedSvdConfig};
use fedsvd::util::human_secs;

fn main() {
    let m = 64usize;
    let n = 256usize;
    let x = synthetic_powerlaw(m, n, 0.01, 9);
    let parts = split_columns(&x, 2).unwrap();

    // run once on the reference link, reprice for the sweeps (identical
    // traffic; only the link model changes — same method as tc-shaping)
    let cfg = FedSvdConfig {
        block_size: 32,
        ..Default::default()
    };
    let t0 = std::time::Instant::now();
    let out = run_fedsvd(&parts, &cfg).unwrap();
    let wall = t0.elapsed().as_secs_f64();

    section("Fig 5(c)", "time vs bandwidth (RTT fixed 50 ms)");
    println!("{:>14} {:>12} {:>12} {:>12}", "bandwidth", "compute", "network", "total");
    for bw_mbps in [10.0f64, 100.0, 1_000.0, 10_000.0] {
        let net_s = out.net.reprice(LinkSpec {
            bandwidth_bps: bw_mbps * 1e6,
            rtt_s: 0.05,
        });
        println!(
            "{:>11} Mbps {:>12} {:>12} {:>12}",
            bw_mbps,
            human_secs(wall),
            human_secs(net_s),
            human_secs(wall + net_s)
        );
    }

    section("Fig 5(d)", "time vs RTT (bandwidth fixed 1 Gb/s)");
    println!("{:>10} {:>12} {:>12} {:>12}", "RTT", "compute", "network", "total");
    for rtt_ms in [1.0f64, 10.0, 50.0, 200.0] {
        let net_s = out.net.reprice(LinkSpec {
            bandwidth_bps: 1e9,
            rtt_s: rtt_ms / 1e3,
        });
        println!(
            "{:>7} ms {:>12} {:>12} {:>12}",
            rtt_ms,
            human_secs(wall),
            human_secs(net_s),
            human_secs(wall + net_s)
        );
    }
    println!(
        "\npaper check: total time degrades gracefully — bandwidth matters\n\
         below ~100 Mbps, RTT adds rounds×latency; no cliff (vs HE whose\n\
         inflated traffic multiplies both sensitivities)"
    );

    fig5_transport();
}

/// Simulated vs real transport bytes for the cluster runtime: the same
/// federation once over the in-process mailbox fabric (metered through
/// `NetSim`) and once over real loopback TCP sockets (wire frames).
fn fig5_transport() {
    section(
        "fig5_transport",
        "cluster SVD: local-sim vs tcp-loopback — JSON rows (transport × shards)",
    );
    let m = 96usize;
    let n = 32usize;
    let x = synthetic_powerlaw(m, n, 0.01, 9);
    let parts = split_columns(&x, 2).unwrap();
    let cfg = FedSvdConfig {
        block_size: 8,
        ..Default::default()
    };
    for shards in [1usize, 2, 4] {
        let ccfg = ClusterConfig {
            shards,
            mem_budget: 8 << 20,
            spill_root: None,
        };
        for tcp in [false, true] {
            let t0 = std::time::Instant::now();
            let (out, stats) = if tcp {
                run_fedsvd_cluster_tcp(&parts, &cfg, &ccfg, CpuBackend::global()).unwrap()
            } else {
                run_fedsvd_cluster(&parts, &cfg, &ccfg, CpuBackend::global()).unwrap()
            };
            let wall = t0.elapsed().as_secs_f64();
            let sim_bytes = out.net.total_bytes();
            println!(
                "{}",
                JsonRow::new()
                    .str("bench", "fig5_transport")
                    .str("transport", &stats.transport)
                    .u64("shards", stats.shards as u64)
                    .f64("wall_s", wall, 6)
                    .u64("sim_bytes", sim_bytes)
                    .u64("real_bytes", stats.real_bytes)
                    .finish()
            );
        }
    }
    println!(
        "\ncheck: real_bytes tracks sim_bytes to within framing overhead\n\
         (24 B/frame headers, handshakes, length prefixes) — the simulated\n\
         model undercounts only protocol envelope, never payload"
    );
}
