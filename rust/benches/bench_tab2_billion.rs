//! Tab. 2 — billion-scale application runs.
//!
//! Paper: PCA on 100K×1M genes (32.3 h), LSA on ML-25M 62K×162K r=256
//! (3.71 h), LR on 1K×50M (13.5 h), all at 1 Gb/s / RTT 50 ms on an
//! 8-core 128 GB box. This bench runs the same three applications at a
//! laptop-scale slice, measures per-element throughput, and extrapolates
//! to the paper's shapes (complexity model: masking O(mnb) + truncated
//! SVD O(mnr) / full SVD O(mn·min) + metered network).

use fedsvd::apps::{lr, lsa, pca};
use fedsvd::bench::section;
use fedsvd::coordinator::{ExecMode, Session};
use fedsvd::data::{movielens_like, regression_task, synthetic_powerlaw};
use fedsvd::linalg::CpuBackend;
use fedsvd::metrics::jsonl::JsonRow;
use fedsvd::metrics::process_peak_rss_bytes;
use fedsvd::protocol::{split_columns, FedSvdConfig};
use fedsvd::util::human_secs;

fn cfg() -> FedSvdConfig {
    FedSvdConfig {
        block_size: 32,
        secagg_batch_rows: 64,
        ..Default::default()
    }
}

fn main() {
    section("Tab 2", "billion-scale applications: measured slice + flops-model extrapolation");

    // calibrate sustained dense-matmul throughput on this machine
    let mut rng = fedsvd::rng::Xoshiro256::seed_from_u64(1);
    let a = fedsvd::linalg::Mat::gaussian(256, 256, &mut rng);
    let b = fedsvd::linalg::Mat::gaussian(256, 256, &mut rng);
    let t0 = std::time::Instant::now();
    for _ in 0..3 {
        std::hint::black_box(fedsvd::linalg::matmul(&a, &b).unwrap());
    }
    let gf_per_s = 3.0 * 2.0 * 256f64.powi(3) / t0.elapsed().as_secs_f64() / 1e9;
    // the paper's box: 8 cores (we are 1); assume linear scaling as theirs did
    let paper_gf = gf_per_s * 8.0;
    println!("calibrated dense throughput: {gf_per_s:.2} GF/s (×8 cores → {paper_gf:.1} GF/s)\n");

    // FedSVD flops model at the paper's b=1000:
    //   masking+unmasking ≈ 4·m·n·b, truncated SVD ≈ 2·m·n·(r+10)·(2·iters),
    //   full SVD (LR) ≈ 2·max·min² (QR-first) + O(min³) Jacobi.
    let fedsvd_est = |m: f64, n: f64, r: Option<f64>| -> f64 {
        let mask = 4.0 * m * n * 1000.0;
        let svd = match r {
            Some(r) => 2.0 * m * n * (r + 10.0) * 14.0,
            None => {
                let (mx, mn) = if m > n { (m, n) } else { (n, m) };
                2.0 * mx * mn * mn + 20.0 * mn * mn * mn
            }
        };
        (mask + svd) / (paper_gf * 1e9)
    };

    println!(
        "{:<6} {:<22} {:>12} {:>14} {:>16} {:>12}",
        "app", "paper size", "slice", "slice time", "extrapolated", "paper"
    );

    // ---- PCA: genes data 100K×1M, top-5 --------------------------------
    {
        let (m, n, r) = (160usize, 400usize, 5usize);
        let x = synthetic_powerlaw(m, n, 0.01, 3);
        let parts = split_columns(&x, 2).unwrap();
        let t0 = std::time::Instant::now();
        let out = pca::run_federated_pca(&parts, r, &cfg(), CpuBackend::global()).unwrap();
        let wall = t0.elapsed().as_secs_f64() + out.protocol.net.sim_elapsed_s();
        let est = fedsvd_est(100_000.0, 1_000_000.0, Some(5.0));
        println!(
            "{:<6} {:<22} {:>12} {:>14} {:>16} {:>12}",
            "PCA",
            "100K×1M (1e11)",
            format!("{m}×{n}"),
            human_secs(wall),
            human_secs(est),
            "32.3 h"
        );
    }

    // ---- LSA: ML-25M 62K×162K, top-256 ----------------------------------
    {
        let (m, n, r) = (160usize, 400usize, 16usize);
        let x = movielens_like(m, n, 5);
        let parts = split_columns(&x, 2).unwrap();
        let t0 = std::time::Instant::now();
        let out = lsa::run_federated_lsa(&parts, r, &cfg(), CpuBackend::global()).unwrap();
        let wall = t0.elapsed().as_secs_f64() + out.protocol.net.sim_elapsed_s();
        let est = fedsvd_est(62_000.0, 162_000.0, Some(256.0));
        println!(
            "{:<6} {:<22} {:>12} {:>14} {:>16} {:>12}",
            "LSA",
            "62K×162K r=256 (1e10)",
            format!("{m}×{n} r={r}"),
            human_secs(wall),
            human_secs(est),
            "3.71 h"
        );
    }

    // ---- LR: synthetic 1K×50M ------------------------------------------
    {
        let (m, n) = (800usize, 24usize);
        let (x, _w, y) = regression_task(m, n, 0.1, 7);
        let parts = split_columns(&x, 2).unwrap();
        let t0 = std::time::Instant::now();
        let out = lr::run_federated_lr(&parts, &y, 0, &cfg(), CpuBackend::global()).unwrap();
        let wall = t0.elapsed().as_secs_f64() + out.protocol.net.sim_elapsed_s();
        let est = fedsvd_est(50_000_000.0, 1_000.0, None);
        println!(
            "{:<6} {:<22} {:>12} {:>14} {:>16} {:>12}",
            "LR",
            "1K×50M (5e10)",
            format!("{m}×{n}"),
            human_secs(wall),
            human_secs(est),
            "13.5 h"
        );
    }

    println!(
        "\npaper check: extrapolations land at the same hours scale as the\n\
         paper's 3.7–32.3 h — billion-scale is *feasible*, unlike the HE\n\
         baseline's years (Fig 2b). Constants differ (their Python stack,\n\
         their exact solver); the order of magnitude is the claim."
    );

    // ---- user-side data ingest: in-memory vs streamed (JSON rows) ------
    // The dataset subsystem's cost model: the same cluster SVD with the
    // user partitions fully resident vs streamed from disk through each
    // on-disk format at two chunk sizes. `user_peak_part_bytes` is the
    // high-water mark of partition rows any user held at once — the
    // number that lets the user side exceed RAM on billion-scale inputs.
    section(
        "Tab 2/ingest",
        "user partition ingest: in-memory vs streamed from disk — JSON rows",
    );
    {
        use fedsvd::cluster::{
            run_app_cluster, run_app_cluster_streamed, ClusterApp, ClusterConfig, UserData,
        };
        use fedsvd::data::{split_matrix, MatrixFormat, RowChunkReader, SplitOptions};

        let (m, n) = (512usize, 96usize);
        let x = synthetic_powerlaw(m, n, 0.01, 13);
        let parts = split_columns(&x, 2).unwrap();
        let ccfg = ClusterConfig {
            shards: 8,
            mem_budget: 64 << 20,
            spill_root: None,
        };
        let emit = |format: &str, chunk_rows: usize, wall_s: f64, part_peak: u64| {
            println!(
                "{}",
                JsonRow::new()
                    .str("bench", "tab2_data_ingest")
                    .u64("m", m as u64)
                    .u64("n", n as u64)
                    .str("format", format)
                    .u64("chunk_rows", chunk_rows as u64)
                    .f64("wall_s", wall_s, 6)
                    .u64("user_peak_rss", process_peak_rss_bytes())
                    .u64("user_peak_part_bytes", part_peak)
                    .finish()
            );
        };

        let t0 = std::time::Instant::now();
        let (out, stats, _) = run_app_cluster(
            &parts,
            &cfg(),
            &ccfg,
            CpuBackend::global(),
            &ClusterApp::None,
        )
        .unwrap();
        std::hint::black_box(&out.s);
        emit("mem", 0, t0.elapsed().as_secs_f64(), stats.user_peak_part_bytes);

        for format in [MatrixFormat::DenseBin, MatrixFormat::Csv] {
            for chunk_rows in [32usize, 128] {
                let dir = std::env::temp_dir().join(format!(
                    "fedsvd_bench_ingest_{}_{}_{}",
                    format.name(),
                    chunk_rows,
                    std::process::id()
                ));
                let _ = std::fs::remove_dir_all(&dir);
                let manifest = split_matrix(
                    &x,
                    &dir,
                    &SplitOptions {
                        users: 2,
                        format,
                        chunk_rows,
                        ..Default::default()
                    },
                )
                .unwrap();
                let readers: Vec<RowChunkReader> = (0..2)
                    .map(|i| manifest.open_partition(&dir, i).unwrap())
                    .collect();
                let atts = manifest.attests();
                let data: Vec<UserData<'_>> = readers
                    .iter()
                    .enumerate()
                    .map(|(i, r)| UserData::Stream {
                        reader: r,
                        chunk_rows,
                        attest: Some(atts[i]),
                    })
                    .collect();
                let t0 = std::time::Instant::now();
                let (out, stats, _) = run_app_cluster_streamed(
                    &data,
                    Some(&atts),
                    &cfg(),
                    &ccfg,
                    CpuBackend::global(),
                    &ClusterApp::None,
                )
                .unwrap();
                std::hint::black_box(&out.s);
                emit(
                    format.name(),
                    chunk_rows,
                    t0.elapsed().as_secs_f64(),
                    stats.user_peak_part_bytes,
                );
                let _ = std::fs::remove_dir_all(&dir);
            }
        }
    }

    // ---- cluster shard-scaling sweep (JSON rows) -----------------------
    // The out-of-core path behind the billion-scale claim, at laptop
    // scale: same matrix, increasing shard counts, CSP budget pinned
    // *below* the masked matrix. One JSON row per shard count, same
    // row style as bench_hotpath's thread-scaling sweep, so the
    // trajectory is trackable across PRs.
    section(
        "Tab 2/cluster",
        "sharded multi-party runtime, CSP budget < masked matrix — JSON rows",
    );
    {
        let (m, n) = (512usize, 96usize);
        let matrix_bytes = (m * n * 8) as u64;
        let mem_budget = 256 * 1024u64; // 256 KiB < 384 KiB matrix
        let x = synthetic_powerlaw(m, n, 0.01, 9);
        let parts = split_columns(&x, 2).unwrap();
        println!(
            "matrix {m}x{n} ({} B), budget {} B\n",
            matrix_bytes, mem_budget
        );
        for shards in [1usize, 2, 4, 8] {
            let session = Session::cpu(cfg()).with_exec(ExecMode::Cluster {
                shards,
                mem_budget,
            });
            let t0 = std::time::Instant::now();
            let (out, report) = session.run_svd(&parts).unwrap();
            let wall_s = t0.elapsed().as_secs_f64();
            let stats = report.cluster.expect("cluster stats");
            assert!(stats.csp_peak_matrix_bytes <= mem_budget);
            std::hint::black_box(&out.s);
            println!(
                "{}",
                JsonRow::new()
                    .str("bench", "tab2_cluster_scaling")
                    .u64("m", m as u64)
                    .u64("n", n as u64)
                    .u64("shards", shards as u64)
                    .u64("mem_budget", mem_budget)
                    .f64("wall_s", wall_s, 6)
                    .f64("net_s", report.net_s, 6)
                    .u64("peak_rss", process_peak_rss_bytes())
                    .u64("total_bytes", report.total_bytes)
                    .u64("csp_peak_matrix_bytes", stats.csp_peak_matrix_bytes)
                    .u64("shard_spills", stats.shard_spills)
                    .finish()
            );
        }
    }
}
