//! Fig. 6 — LR application efficiency.
//! (a) FedSVD-LR vs FATE-like vs SecureML-like, n=1K fixed, m swept
//!     (paper: 100× over SecureML, 10× over FATE).
//! (b,c) LR time vs bandwidth and latency.
//! Plus: a cluster-mode sweep (JSON rows, `exec × shards`) tracking the
//! app-level trajectory of `ExecMode::Cluster` across PRs.

use fedsvd::apps::lr::run_federated_lr;
use fedsvd::baselines::sgd_lr::{run_sgd_lr, SgdFramework};
use fedsvd::bench::section;
use fedsvd::coordinator::{ExecMode, Session};
use fedsvd::data::regression_task;
use fedsvd::linalg::CpuBackend;
use fedsvd::metrics::jsonl::JsonRow;
use fedsvd::net::{presets, LinkSpec};
use fedsvd::paillier;
use fedsvd::protocol::{split_columns, FedSvdConfig};
use fedsvd::rng::Xoshiro256;
use fedsvd::util::human_secs;

fn main() {
    let mut rng = Xoshiro256::seed_from_u64(1);
    // measured crypto costs at the paper's 1024-bit keys drive both models
    let (pk, sk) = paillier::keygen(1024, &mut rng).unwrap();
    let costs = paillier::measure_op_costs(&pk, &sk, 3).unwrap();

    fig6a(&costs);
    fig6bc(&costs);
    fig6_cluster();
}

fn fig6a(costs: &paillier::OpCosts) {
    section(
        "Fig 6(a)",
        "LR end-to-end time: FedSVD vs FATE-like vs SecureML-like (n fixed, m swept)",
    );
    let n = 24usize; // paper: n=1K; scaled with m to keep shape
    println!(
        "{:>8} {:>14} {:>14} {:>14} {:>8} {:>8}",
        "m", "FedSVD", "FATE(100ep)", "SecureML(100ep)", "×FATE", "×SML"
    );
    for m in [200usize, 400, 800, 1600] {
        let (x, _w, y) = regression_task(m, n, 0.1, 3);
        let parts = split_columns(&x, 2).unwrap();
        let cfg = FedSvdConfig {
            block_size: 32,
            secagg_batch_rows: 256,
            ..Default::default()
        };
        let t0 = std::time::Instant::now();
        let out = run_federated_lr(&parts, &y, 0, &cfg, CpuBackend::global()).unwrap();
        let fed = t0.elapsed().as_secs_f64() + out.protocol.net.sim_elapsed_s();

        let fate = run_sgd_lr(&x, &y, 100, 0.5, 2, SgdFramework::Fate, costs,
            presets::paper_default()).unwrap();
        let sml = run_sgd_lr(&x, &y, 100, 0.5, 2, SgdFramework::SecureMl, costs,
            presets::paper_default()).unwrap();
        println!(
            "{m:>8} {:>14} {:>14} {:>14} {:>7.0}× {:>7.0}×",
            human_secs(fed),
            human_secs(fate.est_total_s),
            human_secs(sml.est_total_s),
            fate.est_total_s / fed,
            sml.est_total_s / fed
        );
    }
    println!(
        "\npaper check: the FATE:SecureML ratio is ~1:10 (paper: 10× vs 100×\n\
         relative to FedSVD) — reproduced. FedSVD's absolute margin is wider\n\
         here because at this scaled-down m its one-shot factorization cost\n\
         is trivial; at the paper's 1M–50M samples the masking/SVD work\n\
         narrows the gap to the paper's 10×/100×."
    );
}

fn fig6bc(costs: &paillier::OpCosts) {
    section("Fig 6(b,c)", "LR time vs bandwidth / latency");
    let (x, _w, y) = regression_task(400, 24, 0.1, 5);
    let parts = split_columns(&x, 2).unwrap();
    let cfg = FedSvdConfig {
        block_size: 32,
        ..Default::default()
    };
    let t0 = std::time::Instant::now();
    let out = run_federated_lr(&parts, &y, 0, &cfg, CpuBackend::global()).unwrap();
    let fed_wall = t0.elapsed().as_secs_f64();

    println!("-- (b) bandwidth sweep (RTT 50 ms) --");
    println!("{:>12} {:>12} {:>14} {:>14}", "bandwidth", "FedSVD", "FATE", "SecureML");
    for bw_mbps in [10.0f64, 100.0, 1000.0] {
        let link = LinkSpec { bandwidth_bps: bw_mbps * 1e6, rtt_s: 0.05 };
        let fed = fed_wall + out.protocol.net.reprice(link);
        let fate = run_sgd_lr(&x, &y, 100, 0.5, 2, SgdFramework::Fate, costs, link).unwrap();
        let sml = run_sgd_lr(&x, &y, 100, 0.5, 2, SgdFramework::SecureMl, costs, link).unwrap();
        println!(
            "{:>9} Mbps {:>12} {:>14} {:>14}",
            bw_mbps,
            human_secs(fed),
            human_secs(fate.est_total_s),
            human_secs(sml.est_total_s)
        );
    }

    println!("\n-- (c) latency sweep (1 Gb/s) --");
    println!("{:>10} {:>12} {:>14} {:>14}", "RTT", "FedSVD", "FATE", "SecureML");
    for rtt_ms in [1.0f64, 50.0, 200.0] {
        let link = LinkSpec { bandwidth_bps: 1e9, rtt_s: rtt_ms / 1e3 };
        let fed = fed_wall + out.protocol.net.reprice(link);
        let fate = run_sgd_lr(&x, &y, 100, 0.5, 2, SgdFramework::Fate, costs, link).unwrap();
        let sml = run_sgd_lr(&x, &y, 100, 0.5, 2, SgdFramework::SecureMl, costs, link).unwrap();
        println!(
            "{:>7} ms {:>12} {:>14} {:>14}",
            rtt_ms,
            human_secs(fed),
            human_secs(fate.est_total_s),
            human_secs(sml.est_total_s)
        );
    }
    println!(
        "\npaper check: FedSVD least network-sensitive (few rounds, raw-size\n\
         traffic); SGD baselines pay per-iteration round trips"
    );
}

/// FedSVD-LR through the coordinator seam on both exec modes — one JSON
/// row per (exec, shards), same style as the tab2_cluster_scaling rows,
/// so BENCH_* can track the app-over-cluster trajectory across PRs.
fn fig6_cluster() {
    section(
        "Fig 6/cluster",
        "FedSVD-LR on ExecMode::{Sequential, Cluster} — JSON rows (exec × shards)",
    );
    let (m, n) = (400usize, 24usize);
    let (x, _w, y) = regression_task(m, n, 0.1, 5);
    let parts = split_columns(&x, 2).unwrap();
    let cfg = FedSvdConfig {
        block_size: 32,
        secagg_batch_rows: 256,
        ..Default::default()
    };
    let mem_budget = 64 * 1024u64; // < the 400×24×8 B masked matrix
    assert!(mem_budget < (m * n * 8) as u64);

    let run = |exec: ExecMode, shards: usize| {
        let session = Session::cpu(cfg.clone()).with_exec(exec);
        let t0 = std::time::Instant::now();
        let (out, report) = session.run_lr(&parts, &y, 0).unwrap();
        let wall_s = t0.elapsed().as_secs_f64();
        let exec_name = if shards == 0 { "sequential" } else { "cluster" };
        let peak = report
            .cluster
            .as_ref()
            .map(|s| s.csp_peak_matrix_bytes)
            .unwrap_or(0);
        println!(
            "{}",
            JsonRow::new()
                .str("bench", "fig6_lr_app")
                .str("exec", exec_name)
                .u64("shards", shards as u64)
                .u64("m", m as u64)
                .u64("n", n as u64)
                .f64("wall_s", wall_s, 6)
                .f64("net_s", report.net_s, 6)
                .u64("total_bytes", report.total_bytes)
                .u64("csp_peak_matrix_bytes", peak)
                .f64e("train_mse", out.train_mse, 6)
                .finish()
        );
    };

    run(ExecMode::Sequential, 0);
    for shards in [1usize, 2, 4, 8] {
        run(ExecMode::Cluster { shards, mem_budget }, shards);
    }
}
