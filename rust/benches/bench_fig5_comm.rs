//! Fig. 5(b) — communication size: FedSVD >10× smaller than PPDSVD.
//! Fig. 5(f) — per-user communication vs local data size and user count
//! (linear in nᵢ, flat in k).

use fedsvd::bench::section;
use fedsvd::data::synthetic_powerlaw;
use fedsvd::net::link::USER_BASE;
use fedsvd::protocol::{run_fedsvd, split_columns, FedSvdConfig};
use fedsvd::util::human_bytes;

fn main() {
    fig5b();
    fig5f();
}

fn fig5b() {
    section("Fig 5(b)", "total communication: FedSVD vs PPDSVD (measured vs modeled)");
    println!(
        "{:>8} {:>14} {:>16} {:>8}",
        "n", "FedSVD bytes", "PPDSVD bytes", "ratio"
    );
    let m = 48usize;
    for n in [64usize, 128, 256, 512] {
        let x = synthetic_powerlaw(m, n, 0.01, 3);
        let parts = split_columns(&x, 2).unwrap();
        let out = run_fedsvd(
            &parts,
            &FedSvdConfig {
                block_size: 16,
                ..Default::default()
            },
        )
        .unwrap();
        let fed_bytes = out.net.total_bytes();
        // PPDSVD wire model (matches baselines::ppdsvd::estimate): every
        // data element ships as a 2048-bit ciphertext + cross-covariance
        // results return encrypted
        let ct = 256u64; // 2048-bit ciphertext
        let cross = (n as u64 * n as u64) / 4;
        let he_bytes = (m as u64 * n as u64 + cross) * ct;
        println!(
            "{n:>8} {:>14} {:>16} {:>7.1}×",
            human_bytes(fed_bytes),
            human_bytes(he_bytes),
            he_bytes as f64 / fed_bytes as f64
        );
    }
    println!("\npaper check: FedSVD ≥10× smaller at every n, gap widening with n");
}

fn fig5f() {
    section(
        "Fig 5(f)",
        "per-user communication vs local data size nᵢ and #users",
    );
    let m = 48usize;
    println!(
        "{:>8} {:>8} {:>8} {:>16}",
        "users", "n_i", "n", "bytes/user"
    );
    for k in [2usize, 4, 8] {
        for ni in [32usize, 64, 128] {
            let n = k * ni;
            let x = synthetic_powerlaw(m, n, 0.01, 7);
            let parts = split_columns(&x, k).unwrap();
            let out = run_fedsvd(
                &parts,
                &FedSvdConfig {
                    block_size: 16,
                    ..Default::default()
                },
            )
            .unwrap();
            let u0 = out.net.party(USER_BASE);
            let per_user = u0.bytes_sent + u0.bytes_received;
            println!("{k:>8} {ni:>8} {n:>8} {:>16}", human_bytes(per_user));
        }
    }
    println!(
        "\npaper check: per-user bytes grow linearly with nᵢ;\n\
         weak dependence on user count at fixed nᵢ"
    );
}
